//! WRC — Weight Representation Change (the paper's own compression) and
//! the composed pipelines of Table 3.
//!
//! WRC: a tuple of k weights (k·c bits) is replaced by a WROM address +
//! sign bits. With the paper's fixed formats that is
//!
//! | (W,I) | tuple bits | index bits | rate |
//! |-------|-----------|------------|------|
//! | (8,8) | 24        | 16         | 66.6% (1.5×) |
//! | (6,6) | 24 (4×6)  | 18         | 75.0% (1.3×) |
//! | (4,4) | 24 (6×4)  | 20         | 83.3% (1.2×) |
//!
//! The composed columns apply Huffman over the index stream (`WRC+H`)
//! and pruning before both (`P+WRC+H`).

use super::huffman::{huffman_encode, HuffmanCode};
use super::prune::{prune_magnitude, rle_encode_sparse};
use crate::packing::{Layout, Wrom};

/// `compressed / original` with pretty-printing helpers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompressionRate {
    pub compressed_bits: u64,
    pub original_bits: u64,
}

impl CompressionRate {
    /// Table 3's percentage (smaller = better).
    pub fn percent(&self) -> f64 {
        self.compressed_bits as f64 / self.original_bits as f64 * 100.0
    }

    /// Table 3's `N×` factor.
    pub fn factor(&self) -> f64 {
        self.original_bits as f64 / self.compressed_bits as f64
    }
}

impl std::fmt::Display for CompressionRate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.2}% ({:.1}x)", self.percent(), self.factor())
    }
}

/// Full WRC result for a weight stream.
#[derive(Clone, Debug)]
pub struct WrcResult {
    /// WRC alone, paper's guaranteed fixed format.
    pub wrc: CompressionRate,
    /// Raw weights Huffman-coded (Table 3 column `H`).
    pub huffman_only: CompressionRate,
    /// WRC index stream Huffman-coded (column `WRC + H`).
    pub wrc_huffman: CompressionRate,
    /// Prune -> WRC -> Huffman (column `P + WRC + H`).
    pub prune_wrc_huffman: CompressionRate,
    /// WROM entries created for this stream (on-chip cost, Fig. 7).
    pub wrom_entries: usize,
    pub wrom_bits: u64,
    /// Sparsity used in the pruned column.
    pub prune_sparsity: f64,
}

/// Run the entire Table 3 pipeline for one weight stream at the given
/// layout. `prune_sparsity` follows Deep Compression's conv-layer
/// sparsity (~65% for conv layers; FC layers prune harder but Table 3
/// is conv-only).
pub fn wrc_compress(layout: &Layout, weights: &[i64], prune_sparsity: f64) -> crate::error::Result<WrcResult> {
    let c = layout.c as u64;
    let original_bits = weights.len() as u64 * c;

    // --- WRC alone (guaranteed format) ---
    let mut wrom = Wrom::new(layout.clone());
    let stream = wrom.compress_stream(weights)?;
    let wrc_bits = stream.tuples.len() as u64 * wrom.index_bits_fixed() as u64;
    let wrc = CompressionRate {
        compressed_bits: wrc_bits,
        original_bits,
    };

    // --- H: Huffman over raw quantized weights ---
    let (_, h_bits, book) = huffman_encode(weights);
    let huffman_only = CompressionRate {
        compressed_bits: h_bits + book.table_bits(layout.c),
        original_bits,
    };

    // --- WRC + H: Huffman over the WROM address stream ---
    // Addresses are highly repetitive (few distinct groups dominate a
    // Laplacian weight distribution); sign bits are near-uniform so
    // they stay raw (group_size bits per group).
    let addr_syms: Vec<i64> = stream.tuples.iter().map(|&(a, _)| a as i64).collect();
    let (_, ih_bits, ibook) = huffman_encode(&addr_syms);
    let sign_bits = stream.tuples.len() as u64 * wrom.group_size as u64;
    let wrc_huffman = CompressionRate {
        compressed_bits: ih_bits + sign_bits + ibook.table_bits(wrom.index_bits_fixed()),
        original_bits,
    };

    // --- P + WRC + H ---
    let pr = prune_magnitude(weights, prune_sparsity);
    // Deep-Compression-style: RLE(run,value) over the pruned stream,
    // where the *values* go through WRC+Huffman and the runs through
    // the same Huffman stream.
    let mut wrom_p = Wrom::new(layout.clone());
    let nz: Vec<i64> = pr.pruned.iter().copied().filter(|&v| v != 0).collect();
    let nz_stream = wrom_p.compress_stream(&nz)?;
    let nz_syms: Vec<i64> = nz_stream.tuples.iter().map(|&(a, _)| a as i64).collect();
    let (_, nzh_raw, nzbook) = huffman_encode(&nz_syms);
    let nzh_bits = nzh_raw + nz_stream.tuples.len() as u64 * wrom_p.group_size as u64;
    // run lengths for the zero positions
    let (run_syms, _) = rle_encode_sparse(
        &pr.pruned.iter().map(|&v| if v == 0 { 0 } else { 1 }).collect::<Vec<_>>(),
        4,
        0,
    );
    let runs: Vec<i64> = run_syms.chunks(2).map(|p| p[0]).collect();
    let (_, run_bits, runbook) = huffman_encode(&runs);
    let prune_wrc_huffman = CompressionRate {
        compressed_bits: nzh_bits
            + run_bits
            + nzbook.table_bits(wrom_p.index_bits_fixed())
            + runbook.table_bits(4),
        original_bits,
    };

    Ok(WrcResult {
        wrc,
        huffman_only,
        wrc_huffman,
        prune_wrc_huffman,
        wrom_entries: wrom.len(),
        wrom_bits: wrom.rom_bits(),
        prune_sparsity: pr.sparsity,
    })
}

/// Verify a Huffman book exists for external reporting (re-export used
/// by the report module).
pub fn huffman_mean_bits(stream: &[i64]) -> f64 {
    if stream.is_empty() {
        return 0.0;
    }
    HuffmanCode::build(stream).mean_bits(stream)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn laplacian_weights(n: usize, bits: u32, seed: u64) -> Vec<i64> {
        let mut rng = Rng::new(seed);
        let lim = (1i64 << (bits - 1)) - 1;
        // trained-net regime: bulk of the mass within ~1 LSB of zero
        // (per-tensor max-abs scaling is set by outliers; the paper's
        // own Huffman baseline of 14.65% implies ~1.2 bits/weight)
        let b = (lim as f64 / 127.0).max(0.6);
        (0..n)
            .map(|_| (rng.laplace(b)).round().clamp(-(lim + 1) as f64, lim as f64) as i64)
            .collect()
    }

    #[test]
    fn wrc_guaranteed_rates() {
        for (v, pct) in [(8u32, 66.67), (6, 75.0), (4, 83.33)] {
            let l = Layout::for_bits(v).unwrap();
            let ws = laplacian_weights(3 * 4 * 100, v, 30);
            let r = wrc_compress(&l, &ws, 0.65).unwrap();
            assert!(
                (r.wrc.percent() - pct).abs() < 0.5,
                "v={v}: {} vs {pct}",
                r.wrc.percent()
            );
        }
    }

    #[test]
    fn composed_beats_wrc_alone() {
        let l = Layout::for_bits(8).unwrap();
        let ws = laplacian_weights(120_000, 8, 31);
        let r = wrc_compress(&l, &ws, 0.65).unwrap();
        assert!(r.wrc_huffman.percent() < r.wrc.percent());
        assert!(r.prune_wrc_huffman.percent() < r.wrc_huffman.percent());
        // Table 3 ballpark: WRC+H lands near 10%, P+WRC+H below it.
        assert!(r.wrc_huffman.percent() < 40.0, "{:?}", r.wrc_huffman);
    }

    #[test]
    fn factor_is_inverse_of_percent() {
        let r = CompressionRate {
            compressed_bits: 1,
            original_bits: 10,
        };
        assert!((r.percent() - 10.0).abs() < 1e-12);
        assert!((r.factor() - 10.0).abs() < 1e-12);
    }
}
