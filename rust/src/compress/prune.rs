//! Magnitude pruning + sparse encoding (the `P` stage of Table 3's
//! `P + WRC + H` column; Deep Compression's pruning analogue).
//!
//! Weights below a magnitude threshold (chosen to hit a target sparsity)
//! are zeroed. The sparse stream is stored Deep-Compression style:
//! non-zero values plus run lengths of zeros (4-bit runs with overflow
//! markers, as in Han et al. 2015). The decoder returns typed
//! [`SdmmError::CorruptArtifact`] errors on truncated streams — it is
//! part of the model-artifact cold-load path (`runtime::store`).

use crate::error::{Result, SdmmError};

/// Result of pruning a weight stream.
#[derive(Clone, Debug)]
pub struct PruneResult {
    /// The pruned stream (zeros in place).
    pub pruned: Vec<i64>,
    /// Achieved sparsity (fraction zero).
    pub sparsity: f64,
    /// Threshold used.
    pub threshold: u64,
}

/// Prune the smallest-magnitude weights to reach `target_sparsity`
/// (fraction of zeros). Deterministic: ties at the threshold keep the
/// earlier occurrences.
pub fn prune_magnitude(weights: &[i64], target_sparsity: f64) -> PruneResult {
    assert!((0.0..1.0).contains(&target_sparsity));
    let want_zero = (weights.len() as f64 * target_sparsity).round() as usize;
    let mut mags: Vec<u64> = weights.iter().map(|w| w.unsigned_abs()).collect();
    mags.sort_unstable();
    let threshold = if want_zero == 0 { 0 } else { mags[want_zero - 1] };
    let mut zeroed = 0usize;
    let pruned: Vec<i64> = weights
        .iter()
        .map(|&w| {
            if w.unsigned_abs() <= threshold && zeroed < want_zero {
                zeroed += 1;
                0
            } else {
                w
            }
        })
        .collect();
    PruneResult {
        sparsity: zeroed as f64 / weights.len().max(1) as f64,
        pruned,
        threshold,
    }
}

/// Encode a sparse stream as (zero-run, value) pairs with `run_bits`-bit
/// run lengths (Deep Compression uses 4 for conv): a run longer than
/// the field emits a (max_run, 0) filler. Returns the symbol stream
/// (interleaved runs and values) and its size in bits assuming
/// `value_bits` per value symbol.
pub fn rle_encode_sparse(stream: &[i64], run_bits: u32, value_bits: u32) -> (Vec<i64>, u64) {
    let max_run = (1u64 << run_bits) - 1;
    let mut symbols = Vec::new();
    let mut bits = 0u64;
    let mut run = 0u64;
    for &v in stream {
        if v == 0 {
            run += 1;
            if run == max_run {
                symbols.push(run as i64);
                symbols.push(0);
                bits += run_bits as u64 + value_bits as u64;
                run = 0;
            }
        } else {
            symbols.push(run as i64);
            symbols.push(v);
            bits += run_bits as u64 + value_bits as u64;
            run = 0;
        }
    }
    if run > 0 {
        symbols.push(run as i64);
        symbols.push(0);
        bits += run_bits as u64 + value_bits as u64;
    }
    (symbols, bits)
}

/// Decode the (run, value) stream back to the dense form (inverse of
/// `rle_encode_sparse`); `len` is the original length. A stream that
/// ends before `len` values are recovered (or whose final pair is
/// incomplete) is refused with [`SdmmError::CorruptArtifact`].
pub fn rle_decode_sparse(symbols: &[i64], run_bits: u32, len: usize) -> Result<Vec<i64>> {
    let max_run = (1i64 << run_bits) - 1;
    let mut out = Vec::with_capacity(len);
    let mut it = symbols.chunks(2);
    while out.len() < len {
        let pair = it.next().ok_or_else(|| {
            SdmmError::CorruptArtifact(format!(
                "RLE stream truncated: {} of {len} values decoded",
                out.len()
            ))
        })?;
        if pair.len() != 2 {
            return Err(SdmmError::CorruptArtifact(
                "RLE stream ends mid-pair (run without value)".into(),
            ));
        }
        let (run, val) = (pair[0], pair[1]);
        if !(0..=max_run).contains(&run) {
            return Err(SdmmError::CorruptArtifact(format!(
                "RLE run {run} outside the {run_bits}-bit field"
            )));
        }
        for _ in 0..run {
            out.push(0);
        }
        if val != 0 || run < max_run {
            out.push(val);
        }
    }
    // A trailing (run, 0) pads exactly to len; trim defensively.
    out.truncate(len);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn prune_hits_target() {
        let mut rng = Rng::new(20);
        let ws: Vec<i64> = (0..10_000).map(|_| rng.laplace(10.0).round() as i64).collect();
        let r = prune_magnitude(&ws, 0.9);
        assert!((r.sparsity - 0.9).abs() < 0.01, "sparsity {}", r.sparsity);
        // surviving weights all exceed the threshold
        for &w in &r.pruned {
            assert!(w == 0 || w.unsigned_abs() > 0);
        }
    }

    #[test]
    fn prune_keeps_large_weights() {
        let ws = vec![100i64, 1, -100, 2, 100, -1];
        let r = prune_magnitude(&ws, 0.5);
        assert_eq!(r.pruned[0], 100);
        assert_eq!(r.pruned[2], -100);
        assert_eq!(r.pruned[4], 100);
    }

    #[test]
    fn rle_round_trip() {
        let mut rng = Rng::new(21);
        let ws: Vec<i64> = (0..5000).map(|_| rng.laplace(8.0).round() as i64).collect();
        let pruned = prune_magnitude(&ws, 0.85).pruned;
        let (sym, _) = rle_encode_sparse(&pruned, 4, 8);
        let back = rle_decode_sparse(&sym, 4, pruned.len()).unwrap();
        assert_eq!(back, pruned);
    }

    #[test]
    fn rle_long_runs() {
        let mut s = vec![0i64; 100];
        s.push(7);
        s.extend(vec![0i64; 40]);
        let (sym, _) = rle_encode_sparse(&s, 4, 8);
        assert_eq!(rle_decode_sparse(&sym, 4, s.len()).unwrap(), s);
    }

    #[test]
    fn rle_truncation_is_typed_not_a_panic() {
        let mut s = vec![0i64; 40];
        s.push(9);
        s.extend(vec![0i64; 40]);
        s.push(-3);
        let (sym, _) = rle_encode_sparse(&s, 4, 8);
        // drop the final pair: the decoder must refuse, not expect()-panic
        let err = rle_decode_sparse(&sym[..sym.len() - 2], 4, s.len()).unwrap_err();
        assert!(matches!(err, crate::error::SdmmError::CorruptArtifact(_)), "{err}");
        // a dangling run with no value is refused too
        let err = rle_decode_sparse(&sym[..sym.len() - 1], 4, s.len()).unwrap_err();
        assert!(matches!(err, crate::error::SdmmError::CorruptArtifact(_)), "{err}");
        // an impossible run value is refused
        assert!(matches!(
            rle_decode_sparse(&[99, 0], 4, 5),
            Err(crate::error::SdmmError::CorruptArtifact(_))
        ));
    }

    #[test]
    fn rle_saves_bits_on_sparse() {
        let mut rng = Rng::new(22);
        let ws: Vec<i64> = (0..10_000).map(|_| rng.laplace(8.0).round() as i64).collect();
        let pruned = prune_magnitude(&ws, 0.9).pruned;
        let (_, bits) = rle_encode_sparse(&pruned, 4, 8);
        let dense_bits = 8 * pruned.len() as u64;
        assert!(bits < dense_bits / 3, "rle {bits} vs dense {dense_bits}");
    }

    #[test]
    fn all_zero_stream() {
        let s = vec![0i64; 33];
        let (sym, _) = rle_encode_sparse(&s, 4, 8);
        assert_eq!(rle_decode_sparse(&sym, 4, 33).unwrap(), s);
    }
}
