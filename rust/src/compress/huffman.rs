//! Canonical Huffman coding over i64 symbol streams.
//!
//! Used for the `H`, `WRC + H` and `P + WRC + H` columns of Table 3,
//! and by the compressed model artifacts (`runtime::store`) to code the
//! WROM address stream. The implementation is a complete,
//! self-contained encoder/decoder: frequency count → package-merge-free
//! heap construction → canonical code assignment → bit-packed emission;
//! decode walks the canonical table and returns typed
//! [`SdmmError::CorruptArtifact`] errors on truncated or impossible
//! streams (it never panics on malformed input). Round-trip equality is
//! property-tested.
//!
//! Because the code is *canonical*, a book is fully determined by its
//! `(symbol, code length)` pairs — [`HuffmanCode::lengths`] /
//! [`HuffmanCode::from_lengths`] are the (de)serialization hooks the
//! artifact format uses.

use crate::error::{Result, SdmmError};
use std::collections::HashMap;

/// A canonical Huffman code book.
#[derive(Clone, Debug)]
pub struct HuffmanCode {
    /// symbol -> (code bits, code length); canonical order.
    pub codes: HashMap<i64, (u64, u32)>,
    /// Sorted (length, symbol) list for the decoder.
    canonical: Vec<(u32, i64)>,
}

impl HuffmanCode {
    /// Build from symbol frequencies. Single-symbol streams get a 1-bit
    /// code (the degenerate case Huffman needs special-cased).
    pub fn build(stream: &[i64]) -> HuffmanCode {
        let mut freq: HashMap<i64, u64> = HashMap::new();
        for &s in stream {
            *freq.entry(s).or_insert(0) += 1;
        }
        let lengths = code_lengths(&freq);
        canonicalize(lengths)
    }

    /// Mean code length in bits (the entropy-adjacent quantity Table 3
    /// rates derive from).
    pub fn mean_bits(&self, stream: &[i64]) -> f64 {
        if stream.is_empty() {
            return 0.0;
        }
        let total: u64 = stream
            .iter()
            .map(|s| self.codes[s].1 as u64)
            .sum();
        total as f64 / stream.len() as f64
    }

    /// Code-book storage cost in bits (symbol value + length per entry;
    /// included in every Table 3 rate we report).
    pub fn table_bits(&self, symbol_bits: u32) -> u64 {
        self.codes.len() as u64 * (symbol_bits as u64 + 5)
    }

    /// The `(symbol, code length)` pairs in canonical order — together
    /// with [`from_lengths`](Self::from_lengths) this round-trips the
    /// book exactly (canonical codes are determined by lengths alone),
    /// which is how the model-artifact format serializes it.
    pub fn lengths(&self) -> Vec<(i64, u32)> {
        self.canonical.iter().map(|&(len, sym)| (sym, len)).collect()
    }

    /// Rebuild a book from `(symbol, code length)` pairs (the inverse of
    /// [`lengths`](Self::lengths)). Order does not matter — canonical
    /// assignment sorts by `(length, symbol)`.
    pub fn from_lengths(lengths: Vec<(i64, u32)>) -> HuffmanCode {
        canonicalize(lengths)
    }

    /// Number of distinct symbols in the book.
    pub fn len(&self) -> usize {
        self.codes.len()
    }

    /// True when the book codes no symbol (empty input stream).
    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }
}

/// Compute code lengths with a simple two-queue Huffman construction.
fn code_lengths(freq: &HashMap<i64, u64>) -> Vec<(i64, u32)> {
    if freq.is_empty() {
        return vec![];
    }
    if freq.len() == 1 {
        return vec![(*freq.keys().next().unwrap(), 1)];
    }
    // Node arena: (weight, children or leaf symbol)
    enum Node {
        Leaf(i64),
        Internal(usize, usize),
    }
    let mut arena: Vec<(u64, Node)> = Vec::new();
    let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(u64, usize)>> =
        std::collections::BinaryHeap::new();
    let mut syms: Vec<(&i64, &u64)> = freq.iter().collect();
    syms.sort(); // deterministic tie-breaking
    for (s, w) in syms {
        let id = arena.len();
        arena.push((*w, Node::Leaf(*s)));
        heap.push(std::cmp::Reverse((*w, id)));
    }
    while heap.len() > 1 {
        let std::cmp::Reverse((w1, a)) = heap.pop().unwrap();
        let std::cmp::Reverse((w2, b)) = heap.pop().unwrap();
        let id = arena.len();
        arena.push((w1 + w2, Node::Internal(a, b)));
        heap.push(std::cmp::Reverse((w1 + w2, id)));
    }
    let root = heap.pop().unwrap().0 .1;
    // DFS to collect depths.
    let mut lengths = Vec::new();
    let mut stack = vec![(root, 0u32)];
    while let Some((id, depth)) = stack.pop() {
        match arena[id].1 {
            Node::Leaf(s) => lengths.push((s, depth.max(1))),
            Node::Internal(a, b) => {
                stack.push((a, depth + 1));
                stack.push((b, depth + 1));
            }
        }
    }
    lengths
}

/// Assign canonical codes from (symbol, length) pairs.
fn canonicalize(mut lengths: Vec<(i64, u32)>) -> HuffmanCode {
    lengths.sort_by_key(|&(s, l)| (l, s));
    let mut codes = HashMap::new();
    let mut canonical = Vec::new();
    let mut code = 0u64;
    let mut prev_len = 0u32;
    for (sym, len) in lengths {
        code <<= len - prev_len;
        prev_len = len;
        codes.insert(sym, (code, len));
        canonical.push((len, sym));
        code += 1;
    }
    HuffmanCode { codes, canonical }
}

/// Encode a stream; returns (bit-packed bytes, bit count, code book).
pub fn huffman_encode(stream: &[i64]) -> (Vec<u8>, u64, HuffmanCode) {
    let book = HuffmanCode::build(stream);
    let (bytes, total_bits) = huffman_encode_with(stream, &book)
        .expect("a book built from this stream covers every symbol");
    (bytes, total_bits, book)
}

/// Encode a stream with an *existing* book — the artifact writer path:
/// the book built at compile time is the one serialized, so the stored
/// payload and the recorded rate agree by construction rather than by
/// re-derivation. A symbol the book does not cover is a typed error.
pub fn huffman_encode_with(stream: &[i64], book: &HuffmanCode) -> Result<(Vec<u8>, u64)> {
    let mut bytes = Vec::new();
    let mut acc = 0u64;
    let mut nbits = 0u32;
    let mut total_bits = 0u64;
    for s in stream {
        let &(code, len) = book.codes.get(s).ok_or_else(|| {
            SdmmError::CorruptArtifact(format!("symbol {s} missing from the Huffman book"))
        })?;
        total_bits += len as u64;
        // append MSB-first
        for i in (0..len).rev() {
            acc = (acc << 1) | ((code >> i) & 1);
            nbits += 1;
            if nbits == 8 {
                bytes.push(acc as u8);
                acc = 0;
                nbits = 0;
            }
        }
    }
    if nbits > 0 {
        bytes.push((acc << (8 - nbits)) as u8);
    }
    Ok((bytes, total_bits))
}

/// Decode `count` symbols. Malformed input — a stream that runs out of
/// bits mid-code, or a bit pattern no canonical code matches — yields a
/// typed [`SdmmError::CorruptArtifact`], never a panic (this is the
/// artifact cold-load path).
pub fn huffman_decode(bytes: &[u8], count: usize, book: &HuffmanCode) -> Result<Vec<i64>> {
    if count == 0 {
        return Ok(Vec::new());
    }
    if book.canonical.is_empty() {
        return Err(SdmmError::CorruptArtifact(
            "huffman stream with an empty code book".into(),
        ));
    }
    // Rebuild first-code tables for canonical decode.
    // first_code[len], first_index[len]
    let max_len = book.canonical.iter().map(|&(l, _)| l).max().unwrap_or(0);
    let mut first_code = vec![0u64; (max_len + 2) as usize];
    let mut first_idx = vec![0usize; (max_len + 2) as usize];
    {
        let mut code = 0u64;
        let mut idx = 0usize;
        let mut prev_len = 0u32;
        for &(len, _) in &book.canonical {
            code <<= len - prev_len;
            if len != prev_len {
                first_code[len as usize] = code;
                first_idx[len as usize] = idx;
                prev_len = len;
            }
            code += 1;
            idx += 1;
        }
    }
    // count of codes per length
    let mut per_len = vec![0usize; (max_len + 2) as usize];
    for &(l, _) in &book.canonical {
        per_len[l as usize] += 1;
    }

    let mut out = Vec::with_capacity(count);
    let mut bitpos = 0usize;
    let total_bits = bytes.len() * 8;
    while out.len() < count {
        let mut code = 0u64;
        let mut len = 0u32;
        loop {
            if len >= max_len {
                return Err(SdmmError::CorruptArtifact(format!(
                    "huffman stream: no code matches within the book's max length {max_len}"
                )));
            }
            if bitpos >= total_bits {
                return Err(SdmmError::CorruptArtifact(format!(
                    "huffman stream truncated: {} of {count} symbols decoded",
                    out.len()
                )));
            }
            code = (code << 1) | ((bytes[bitpos / 8] >> (7 - bitpos % 8)) & 1) as u64;
            bitpos += 1;
            len += 1;
            let l = len as usize;
            if per_len[l] > 0 {
                let offset = code.wrapping_sub(first_code[l]);
                if code >= first_code[l] && (offset as usize) < per_len[l] {
                    out.push(book.canonical[first_idx[l] + offset as usize].1);
                    break;
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_skewed() {
        let mut rng = Rng::new(10);
        let stream: Vec<i64> = (0..5000)
            .map(|_| (rng.laplace(3.0)).round() as i64)
            .collect();
        let (bytes, bits, book) = huffman_encode(&stream);
        assert!(bits <= bytes.len() as u64 * 8);
        let back = huffman_decode(&bytes, stream.len(), &book).unwrap();
        assert_eq!(back, stream);
    }

    #[test]
    fn round_trip_uniform() {
        let mut rng = Rng::new(11);
        let stream: Vec<i64> = (0..2000).map(|_| rng.range_i64(-128, 127)).collect();
        let (bytes, _, book) = huffman_encode(&stream);
        assert_eq!(huffman_decode(&bytes, stream.len(), &book).unwrap(), stream);
    }

    #[test]
    fn single_symbol_stream() {
        let stream = vec![42i64; 100];
        let (bytes, bits, book) = huffman_encode(&stream);
        assert_eq!(bits, 100); // 1 bit per symbol
        assert_eq!(huffman_decode(&bytes, 100, &book).unwrap(), stream);
    }

    #[test]
    fn truncated_stream_is_typed_not_a_panic() {
        let mut rng = Rng::new(14);
        let stream: Vec<i64> = (0..500).map(|_| rng.laplace(3.0).round() as i64).collect();
        let (bytes, _, book) = huffman_encode(&stream);
        // ask for more symbols than the bytes can possibly hold
        let err = huffman_decode(&bytes[..bytes.len() / 4], stream.len(), &book).unwrap_err();
        assert!(matches!(err, crate::error::SdmmError::CorruptArtifact(_)), "{err}");
        // empty book with a non-zero count is refused, not indexed
        let empty = HuffmanCode::build(&[]);
        assert!(matches!(
            huffman_decode(&[0xff], 1, &empty),
            Err(crate::error::SdmmError::CorruptArtifact(_))
        ));
    }

    #[test]
    fn encode_with_matches_encode_and_rejects_unknown_symbols() {
        let mut rng = Rng::new(16);
        let stream: Vec<i64> = (0..2000).map(|_| rng.laplace(2.5).round() as i64).collect();
        let (bytes, bits, book) = huffman_encode(&stream);
        let (bytes2, bits2) = huffman_encode_with(&stream, &book).unwrap();
        assert_eq!((bytes, bits), (bytes2, bits2));
        // a symbol the book does not cover is a typed refusal
        assert!(matches!(
            huffman_encode_with(&[i64::MAX], &book),
            Err(crate::error::SdmmError::CorruptArtifact(_))
        ));
    }

    #[test]
    fn lengths_round_trip_the_book() {
        let mut rng = Rng::new(15);
        let stream: Vec<i64> = (0..3000).map(|_| rng.laplace(4.0).round() as i64).collect();
        let (bytes, _, book) = huffman_encode(&stream);
        let rebuilt = HuffmanCode::from_lengths(book.lengths());
        assert_eq!(rebuilt.codes, book.codes);
        assert_eq!(rebuilt.len(), book.len());
        // the rebuilt book decodes the original emission bit-exactly
        assert_eq!(huffman_decode(&bytes, stream.len(), &rebuilt).unwrap(), stream);
    }

    #[test]
    fn skewed_beats_uniform_rate() {
        let mut rng = Rng::new(12);
        let skewed: Vec<i64> = (0..4000).map(|_| rng.laplace(2.0).round() as i64).collect();
        let uniform: Vec<i64> = (0..4000).map(|_| rng.range_i64(-128, 127)).collect();
        let bs = HuffmanCode::build(&skewed).mean_bits(&skewed);
        let bu = HuffmanCode::build(&uniform).mean_bits(&uniform);
        assert!(bs < bu, "skewed {bs} >= uniform {bu}");
        assert!(bs < 5.0, "Laplacian 8-bit weights compress below 5 b/sym");
    }

    #[test]
    fn mean_bits_close_to_entropy() {
        let mut rng = Rng::new(13);
        let stream: Vec<i64> = (0..8000).map(|_| rng.laplace(4.0).round() as i64).collect();
        let book = HuffmanCode::build(&stream);
        // empirical entropy
        let mut freq = std::collections::HashMap::new();
        for &s in &stream {
            *freq.entry(s).or_insert(0u64) += 1;
        }
        let n = stream.len() as f64;
        let h: f64 = freq
            .values()
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum();
        let mean = book.mean_bits(&stream);
        assert!(mean >= h - 1e-9 && mean <= h + 1.0, "H={h} mean={mean}");
    }

    #[test]
    fn deterministic_codebook() {
        let s = vec![1i64, 2, 2, 3, 3, 3];
        let a = HuffmanCode::build(&s);
        let b = HuffmanCode::build(&s);
        assert_eq!(a.codes, b.codes);
    }
}
