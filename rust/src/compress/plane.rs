//! The off-chip representation of a compiled model: [`CompressionPolicy`]
//! (the compile pipeline's compression stage) and [`CompressedPlane`]
//! (one conv layer's packed plane in its stored form).
//!
//! This is the paper's deployment story made concrete (§5, Table 3):
//! SDMM parameters live in a *different format off-chip* — per weight
//! group only a WROM address plus sign bits (WRC, a guaranteed
//! 33%/25%/16.7% reduction), optionally Huffman-coded (`WRC + H`) and
//! preceded by magnitude pruning (`P + WRC + H`). A `CompressedPlane`
//! is what `CompiledModel::save` writes into the `sdmm-model.bin`
//! artifact and what the registry cold-load decodes back into
//! WROM-backed planes without repacking (DESIGN.md §8).

use super::huffman::{huffman_encode, HuffmanCode};
use super::prune::rle_encode_sparse;
use super::wrc::CompressionRate;
use crate::error::{Result, SdmmError};
use crate::packing::{Wrom, WromIndexStream};

/// Default conv-layer prune sparsity for
/// [`CompressionPolicy::PruneWrcHuffman`] (Deep Compression's ~65%
/// conv-layer figure, the one Table 3 assumes).
pub const DEFAULT_PRUNE_SPARSITY: f64 = 0.65;

/// How a compiled model stores its parameters off-chip — the third
/// stage of the compile pipeline
/// (`Compiler::for_bits(v)?.approximate(p).compress(policy)`), matching
/// Table 3's columns.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CompressionPolicy {
    /// Raw packed planes only; the artifact stores plain effective
    /// weights (the baseline — no off-chip compression).
    #[default]
    None,
    /// Weight Representation Change: per group a fixed-width
    /// `{WROM address, sign bits}` word — the paper's guaranteed
    /// 66.6%/75%/83.3% of raw for 8/6/4-bit.
    Wrc,
    /// WRC with the address stream canonical-Huffman coded
    /// (Table 3's `WRC + H` column); sign bits stay raw (near-uniform).
    WrcHuffman,
    /// Magnitude pruning *before packing* (the model itself is pruned),
    /// then WRC with an RLE map over all-zero groups and Huffman over
    /// the surviving addresses (Table 3's `P + WRC + H` column).
    PruneWrcHuffman,
}

impl CompressionPolicy {
    /// Short stable name (manifest field, reports, CLI output).
    pub fn name(&self) -> &'static str {
        match self {
            CompressionPolicy::None => "none",
            CompressionPolicy::Wrc => "wrc",
            CompressionPolicy::WrcHuffman => "wrc+h",
            CompressionPolicy::PruneWrcHuffman => "p+wrc+h",
        }
    }

    /// Parse a policy name (CLI `--policy`, manifest round-trip).
    /// Accepts the canonical [`name`](Self::name) forms plus the
    /// spelled-out CLI aliases.
    pub fn parse(s: &str) -> Result<CompressionPolicy> {
        match s {
            "none" | "raw" => Ok(CompressionPolicy::None),
            "wrc" => Ok(CompressionPolicy::Wrc),
            "wrc+h" | "wrc-huffman" | "wrch" => Ok(CompressionPolicy::WrcHuffman),
            "p+wrc+h" | "prune-wrc-huffman" | "pwrch" => Ok(CompressionPolicy::PruneWrcHuffman),
            other => Err(SdmmError::Parse(format!(
                "unknown compression policy {other:?} \
                 (none|wrc|wrc-huffman|prune-wrc-huffman)"
            ))),
        }
    }

    /// Stable on-disk tag (artifact header byte).
    pub fn tag(&self) -> u8 {
        match self {
            CompressionPolicy::None => 0,
            CompressionPolicy::Wrc => 1,
            CompressionPolicy::WrcHuffman => 2,
            CompressionPolicy::PruneWrcHuffman => 3,
        }
    }

    /// Inverse of [`tag`](Self::tag); unknown tags are a typed
    /// [`SdmmError::CorruptArtifact`].
    pub fn from_tag(tag: u8) -> Result<CompressionPolicy> {
        match tag {
            0 => Ok(CompressionPolicy::None),
            1 => Ok(CompressionPolicy::Wrc),
            2 => Ok(CompressionPolicy::WrcHuffman),
            3 => Ok(CompressionPolicy::PruneWrcHuffman),
            other => Err(SdmmError::CorruptArtifact(format!(
                "unknown compression policy tag {other}"
            ))),
        }
    }

    /// True for every policy that stores an index stream (everything
    /// but [`CompressionPolicy::None`]).
    pub fn compresses(&self) -> bool {
        !matches!(self, CompressionPolicy::None)
    }

    /// True when the policy prunes weights before packing (the model's
    /// effective weights change, not just their storage).
    pub fn prunes(&self) -> bool {
        matches!(self, CompressionPolicy::PruneWrcHuffman)
    }
}

impl std::fmt::Display for CompressionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Is the stream group at `(addr, signs)` the all-zero magnitude group?
/// (Shared by the rate accounting here and the artifact writer, so the
/// RLE map and the stored non-zero stream can never disagree.)
pub(crate) fn is_zero_group(wrom: &Wrom, addr: u32, signs: u32) -> bool {
    signs == 0
        && (addr as usize) < wrom.len()
        && wrom.entry(addr).slots.iter().all(|s| s.zero)
}

/// One conv layer's packed plane in its off-chip (artifact) form: the
/// WRC index stream plus the policy's transport coding, and the rate it
/// achieves against the raw quantized weights.
#[derive(Clone, Debug)]
pub struct CompressedPlane {
    /// Policy this plane was encoded under (never
    /// [`CompressionPolicy::None`]).
    pub policy: CompressionPolicy,
    /// `(WROM address, sign bits)` per paper-sized weight group, over
    /// the plane's canonical tuple order (the form
    /// [`PackedPlane::to_index_stream`](crate::packing::PackedPlane::to_index_stream)
    /// produces).
    pub stream: WromIndexStream,
    /// Canonical Huffman book over the stored address symbols
    /// (`WrcHuffman` / `PruneWrcHuffman`; `None` for plain `Wrc`).
    pub huffman: Option<HuffmanCode>,
    /// `PruneWrcHuffman`: interleaved `(zero-run, marker)` RLE symbols
    /// over the group stream (4-bit runs, marker 1 = one stored
    /// non-zero group follows, 0 = run-overflow filler).
    pub zero_rle: Option<Vec<i64>>,
    /// Groups whose `(address, signs)` are physically stored — all of
    /// them except under `PruneWrcHuffman`, where all-zero groups live
    /// only in the RLE map.
    pub stored_groups: usize,
    /// Off-chip footprint vs the raw quantized weights (Table 3's
    /// accounting: payload + code books; the on-chip WROM is costed
    /// separately, Fig. 7).
    pub rate: CompressionRate,
}

impl CompressedPlane {
    /// Encode a layer's index stream under `policy`. `wrom` is the
    /// model-wide ROM the stream's addresses point into (fully built —
    /// the address field width depends on the final entry count);
    /// `original_bits` is the layer's raw footprint
    /// (`params × c_bits`).
    pub fn build(
        policy: CompressionPolicy,
        stream: WromIndexStream,
        wrom: &Wrom,
        original_bits: u64,
    ) -> Result<CompressedPlane> {
        if !policy.compresses() {
            return Err(SdmmError::InvalidConfig(
                "CompressedPlane::build needs a compressing policy".into(),
            ));
        }
        for &(addr, _) in &stream.tuples {
            if addr as usize >= wrom.len() {
                return Err(SdmmError::CorruptArtifact(format!(
                    "index stream address {addr} outside the {}-entry WROM",
                    wrom.len()
                )));
            }
        }
        let gs = wrom.group_size as u64;
        let index_bits = wrom.index_bits_actual() as u64;
        let addr_bits = (index_bits - gs) as u32;
        let n_groups = stream.tuples.len() as u64;
        match policy {
            CompressionPolicy::None => unreachable!("checked above"),
            CompressionPolicy::Wrc => Ok(CompressedPlane {
                policy,
                stored_groups: stream.tuples.len(),
                stream,
                huffman: None,
                zero_rle: None,
                rate: super::rate(n_groups * index_bits, original_bits),
            }),
            CompressionPolicy::WrcHuffman => {
                let addrs: Vec<i64> =
                    stream.tuples.iter().map(|&(a, _)| a as i64).collect();
                let (_, h_bits, book) = huffman_encode(&addrs);
                let bits = h_bits + book.table_bits(addr_bits) + n_groups * gs;
                Ok(CompressedPlane {
                    policy,
                    stored_groups: stream.tuples.len(),
                    stream,
                    huffman: Some(book),
                    zero_rle: None,
                    rate: super::rate(bits, original_bits),
                })
            }
            CompressionPolicy::PruneWrcHuffman => {
                // 1 = group physically stored, 0 = all-zero group
                // (lives in the RLE map only).
                let indicator: Vec<i64> = stream
                    .tuples
                    .iter()
                    .map(|&(a, s)| i64::from(!is_zero_group(wrom, a, s)))
                    .collect();
                let (rle, _) = rle_encode_sparse(&indicator, 4, 0);
                let nz_addrs: Vec<i64> = stream
                    .tuples
                    .iter()
                    .zip(&indicator)
                    .filter(|&(_, &ind)| ind != 0)
                    .map(|(&(a, _), _)| a as i64)
                    .collect();
                let (_, h_bits, book) = huffman_encode(&nz_addrs);
                let nz = nz_addrs.len() as u64;
                // 5 bits per RLE pair: 4-bit run + 1-bit marker.
                let bits = (rle.len() as u64 / 2) * 5
                    + h_bits
                    + book.table_bits(addr_bits)
                    + nz * gs;
                Ok(CompressedPlane {
                    policy,
                    stored_groups: nz as usize,
                    stream,
                    huffman: Some(book),
                    zero_rle: Some(rle),
                    rate: super::rate(bits, original_bits),
                })
            }
        }
    }

    /// Reassemble a plane from parts the artifact reader already holds
    /// (decoded stream, stored book/RLE map, payload bit counts) — the
    /// cold-load path must not re-run `huffman_encode` just to recover
    /// the rate. The caller (`runtime::store`) guarantees the parts
    /// came from one consistent payload; `CompressedPlane::build` is
    /// the validating front door for everything else.
    pub(crate) fn from_parts(
        policy: CompressionPolicy,
        stream: WromIndexStream,
        huffman: Option<HuffmanCode>,
        zero_rle: Option<Vec<i64>>,
        stored_groups: usize,
        compressed_bits: u64,
        original_bits: u64,
    ) -> CompressedPlane {
        CompressedPlane {
            policy,
            stream,
            huffman,
            zero_rle,
            stored_groups,
            rate: super::rate(compressed_bits, original_bits),
        }
    }

    /// Weight groups in the stream (stored + RLE-elided).
    pub fn groups(&self) -> usize {
        self.stream.tuples.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::Layout;
    use crate::util::rng::Rng;

    fn laplacian(n: usize, bits: u32, seed: u64) -> Vec<i64> {
        let mut rng = Rng::new(seed);
        let lim = (1i64 << (bits - 1)) - 1;
        let b = (lim as f64 / 127.0).max(0.6);
        (0..n)
            .map(|_| rng.laplace(b).round().clamp(-(lim + 1) as f64, lim as f64) as i64)
            .collect()
    }

    fn stream_for(ws: &[i64], bits: u32) -> (Wrom, WromIndexStream) {
        let mut wrom = Wrom::new(Layout::for_bits(bits).unwrap());
        let stream = wrom.compress_stream(ws).unwrap();
        (wrom, stream)
    }

    #[test]
    fn policy_names_round_trip() {
        for p in [
            CompressionPolicy::None,
            CompressionPolicy::Wrc,
            CompressionPolicy::WrcHuffman,
            CompressionPolicy::PruneWrcHuffman,
        ] {
            assert_eq!(CompressionPolicy::parse(p.name()).unwrap(), p);
            assert_eq!(CompressionPolicy::from_tag(p.tag()).unwrap(), p);
        }
        assert!(CompressionPolicy::parse("gzip").is_err());
        assert!(CompressionPolicy::from_tag(9).is_err());
    }

    #[test]
    fn wrc_rate_matches_guarantee() {
        for (bits, pct) in [(8u32, 66.67), (6, 75.0), (4, 83.33)] {
            let ws = laplacian(12 * 500, bits, 70);
            let (wrom, stream) = stream_for(&ws, bits);
            let cp = CompressedPlane::build(
                CompressionPolicy::Wrc,
                stream,
                &wrom,
                ws.len() as u64 * bits as u64,
            )
            .unwrap();
            assert!(
                (cp.rate.percent() - pct).abs() < 0.5,
                "bits={bits}: {} vs {pct}",
                cp.rate.percent()
            );
            assert!(cp.huffman.is_none() && cp.zero_rle.is_none());
            assert_eq!(cp.stored_groups, cp.groups());
        }
    }

    #[test]
    fn huffman_policy_beats_wrc_on_peaky_weights() {
        let ws = laplacian(30_000, 8, 71);
        let (wrom, stream) = stream_for(&ws, 8);
        let raw = ws.len() as u64 * 8;
        let wrc =
            CompressedPlane::build(CompressionPolicy::Wrc, stream.clone(), &wrom, raw).unwrap();
        let wh = CompressedPlane::build(CompressionPolicy::WrcHuffman, stream, &wrom, raw)
            .unwrap();
        assert!(wh.rate.percent() < wrc.rate.percent(), "{:?} vs {:?}", wh.rate, wrc.rate);
        assert!(wh.huffman.is_some());
    }

    #[test]
    fn pruned_policy_maps_zero_groups() {
        // Pre-pruned stream: mostly zeros, as the compiler produces
        // under PruneWrcHuffman.
        let mut ws = laplacian(9000, 8, 72);
        for (i, w) in ws.iter_mut().enumerate() {
            if i % 4 != 0 {
                *w = 0;
            }
        }
        let (wrom, stream) = stream_for(&ws, 8);
        let raw = ws.len() as u64 * 8;
        let wrc =
            CompressedPlane::build(CompressionPolicy::Wrc, stream.clone(), &wrom, raw).unwrap();
        let p = CompressedPlane::build(CompressionPolicy::PruneWrcHuffman, stream, &wrom, raw)
            .unwrap();
        assert!(p.zero_rle.is_some());
        assert!(p.stored_groups < p.groups());
        // eliding zero groups + coding only surviving addresses beats
        // the fixed-width format comfortably on a mostly-zero stream
        assert!(p.rate.percent() < wrc.rate.percent(), "{:?} vs {:?}", p.rate, wrc.rate);
    }
}
