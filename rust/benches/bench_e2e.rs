//! End-to-end benchmarks.
//!
//! Part 1 (always runs): a native 3-conv integer CNN through the
//! systolic-array simulator, scalar engine vs batch engine with reused
//! weight planes — the end-to-end half of the scalar-vs-batch
//! comparison recorded in EXPERIMENTS.md §Perf.
//!
//! Part 2 (PJRT serving): the coordinator (dynamic batcher + worker
//! thread + PJRT executable) under closed-loop load. Skips when the
//! artifacts are missing or the `pjrt` feature is off.

use sdmm::cnn::infer::{relu, requantize, Tensor3};
use sdmm::cnn::zoo::ConvLayer;
use sdmm::packing::PackedPlane;
use sdmm::sa::{PeArch, SaConfig, SystolicArray};
use sdmm::util::bench::BenchSuite;
use sdmm::util::rng::Rng;

fn native_layers() -> Vec<ConvLayer> {
    vec![
        ConvLayer::new("c1", 16, 8, 16, 3, 1, 1, 1),
        ConvLayer::new("c2", 16, 16, 16, 3, 1, 1, 1),
        ConvLayer::new("c3", 16, 16, 24, 3, 1, 1, 1),
    ]
}

/// Run the native network; `conv` executes one conv layer.
fn forward(
    layers: &[ConvLayer],
    input: &Tensor3,
    mut conv: impl FnMut(usize, &Tensor3) -> Tensor3,
) -> Tensor3 {
    let mut x = input.clone();
    for i in 0..layers.len() {
        let mut y = conv(i, &x);
        relu(&mut y);
        x = requantize(&y, 8).0;
    }
    x
}

fn bench_native(suite: &mut BenchSuite) {
    let layers = native_layers();
    let mut rng = Rng::new(17);
    let weights: Vec<Vec<i64>> = layers
        .iter()
        .map(|l| (0..l.params()).map(|_| rng.range_i64(-128, 127)).collect())
        .collect();
    let mut input = Tensor3::zeros(layers[0].in_ch, layers[0].in_hw, layers[0].in_hw);
    input.data = (0..input.data.len()).map(|_| rng.range_i64(-128, 127)).collect();
    let macs: u64 = layers.iter().map(|l| l.macs()).sum();

    let sa = SystolicArray::new(SaConfig::paper_prototype(8, PeArch::MultiPack)).unwrap();
    let planes: Vec<PackedPlane> = layers
        .iter()
        .zip(&weights)
        .map(|(l, w)| sa.pack_plane(l, w).unwrap())
        .collect();

    // identical outputs before timing
    let out_scalar = forward(&layers, &input, |i, x| {
        sa.run_conv(&layers[i], &weights[i], x).unwrap().output.unwrap()
    });
    let out_batch = forward(&layers, &input, |i, x| {
        sa.run_conv_batch_with_plane(&layers[i], &planes[i], x)
            .unwrap()
            .output
            .unwrap()
    });
    assert_eq!(out_scalar, out_batch, "e2e paths diverged");

    suite.bench("native 3-conv e2e (scalar engine)", macs as f64, || {
        forward(&layers, &input, |i, x| {
            sa.run_conv(&layers[i], &weights[i], x).unwrap().output.unwrap()
        })
        .data[0]
    });
    suite.bench("native 3-conv e2e (batch engine + planes)", macs as f64, || {
        forward(&layers, &input, |i, x| {
            sa.run_conv_batch_with_plane(&layers[i], &planes[i], x)
                .unwrap()
                .output
                .unwrap()
        })
        .data[0]
    });
}

fn main() {
    let mut suite = BenchSuite::new("e2e");
    bench_native(&mut suite);
    serving(&mut suite);
    suite.run();
}

#[cfg(not(feature = "pjrt"))]
fn serving(_suite: &mut BenchSuite) {
    println!("SKIP e2e serving: built without the `pjrt` feature");
}

#[cfg(feature = "pjrt")]
fn serving(suite: &mut BenchSuite) {
    use sdmm::coordinator::{BatchPolicy, CnnRunner, InferenceServer};
    use sdmm::runtime::{artifacts_available, Artifacts, WeightMode};

    let dir = "artifacts";
    if !artifacts_available(dir) {
        println!("SKIP e2e serving: artifacts/ missing (run `make artifacts`)");
        return;
    }
    let art = Artifacts::load(dir).unwrap();
    let xs = art.f32("eval_x").unwrap();
    let item = 16 * 16;

    for (name, conc) in [("closed-loop c=1", 1usize), ("closed-loop c=64", 64)] {
        let server = InferenceServer::start_factory(
            move || CnnRunner::load("artifacts", WeightMode::Approximated { w_bits: 8 }),
            BatchPolicy::default(),
        );
        // warm the pipeline
        let _ = server.infer(xs[..item].to_vec());
        let requests = if conc == 1 { 64 } else { 512 };
        suite.bench(&format!("{name} ({requests} req)"), requests as f64, || {
            let mut inflight = std::collections::VecDeque::new();
            let (mut sent, mut done) = (0usize, 0usize);
            while done < requests {
                while inflight.len() < conc && sent < requests {
                    let off = (sent * item) % (xs.len() - item);
                    inflight.push_back(server.submit(xs[off..off + item].to_vec()));
                    sent += 1;
                }
                if let Some(rx) = inflight.pop_front() {
                    rx.recv().unwrap().unwrap();
                    done += 1;
                }
            }
            done
        });
        let m = server.shutdown();
        println!(
            "  -> latency p50 {:.2}ms p99 {:.2}ms, occupancy {:.1}%",
            m.latency.p50() / 1e6,
            m.latency.p99() / 1e6,
            m.batch_occupancy(16) * 100.0
        );
    }
}
