//! End-to-end benchmarks.
//!
//! Part 1: a native 3-conv integer CNN through the systolic-array
//! simulator, scalar engine vs batch engine with reused weight planes
//! — the end-to-end half of the scalar-vs-batch comparison recorded in
//! EXPERIMENTS.md §Perf.
//!
//! Part 2 (PJRT serving): the coordinator (dynamic batcher + worker
//! thread + PJRT executable) under closed-loop load. Skips when the
//! artifacts are missing or the `pjrt` feature is off.
//!
//! Part 3 (sharded serving, EXPERIMENTS.md §Serving): the multi-model
//! `ServingRuntime` under closed-loop load over a mixed 8/6/4-bit
//! model set, measuring throughput scaling across 1/2/4 shards. This
//! part runs *instead of* parts 1–2 when invoked as
//! `cargo bench --bench bench_e2e -- --serving` (so the CI smoke
//! matrix runs each part exactly once). Intra-op parallelism is
//! pinned to one thread (`SDMM_THREADS=1`) so the scaling measured is
//! the shards', not the conv tiler's.
//!
//! Part 5 (`-- --network`): whole-network inference through the
//! `api::network` pipeline (NetworkPlan + InferenceSession) on all
//! four executor backends, gated bit-identical against the exact
//! scalar reference before timing.
//!
//! Part 7 (`-- --daemon`, also in the default run so the perf gate
//! sees its rows): the `sdmm serve` TCP daemon over loopback from one
//! persistent connection — a single interactive round-trip per
//! iteration, then a pipelined batch of 16 batch-QoS requests per
//! iteration (EXPERIMENTS.md §Open-loop serving protocol).
//!
//! Part 8 (default run): the packing-generation matrix — one BatchExec
//! conv row per generation (DSP48E1 baseline / overpacked / DSP58) at
//! 8 and 6 bits, each gated scalar≡batch bit-exact, asserting the
//! overpacked generation's strictly-fewer-DSP-ops acceptance bound
//! before timing.

use sdmm::api::{ApproxPolicy, BatchExec, Compiler, Executor, ScalarExec, SystolicExec};
use sdmm::cnn::infer::{relu, requantize, Tensor3};
use sdmm::cnn::zoo::ConvLayer;
use sdmm::coordinator::{ModelKey, ModelRegistry, ModelSpec, ServingConfig, ServingRuntime};
use sdmm::dsp::{Isa, PackGeneration};
use sdmm::report::serving_summary;
use sdmm::sa::{PeArch, SaConfig, SystolicArray};
use sdmm::util::bench::{write_snapshot, BenchSuite};
use sdmm::util::rng::Rng;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

fn native_layers() -> Vec<ConvLayer> {
    vec![
        ConvLayer::new("c1", 16, 8, 16, 3, 1, 1, 1),
        ConvLayer::new("c2", 16, 16, 16, 3, 1, 1, 1),
        ConvLayer::new("c3", 16, 16, 24, 3, 1, 1, 1),
    ]
}

fn bench_native(suite: &mut BenchSuite) {
    let layers = native_layers();
    let mut rng = Rng::new(17);
    let weights: Vec<Vec<i64>> = layers
        .iter()
        .map(|l| (0..l.params()).map(|_| rng.range_i64(-128, 127)).collect())
        .collect();
    let mut input = Tensor3::zeros(layers[0].in_ch, layers[0].in_hw, layers[0].in_hw);
    input.data = (0..input.data.len()).map(|_| rng.range_i64(-128, 127)).collect();
    let macs: u64 = layers.iter().map(|l| l.macs()).sum();

    // One compile through the api facade; every backend below shares
    // the resulting planes.
    // skip_stats: benches never read the per-layer error sweep.
    let model = Compiler::for_bits(8)
        .unwrap()
        .approximate(ApproxPolicy { skip_stats: true, ..ApproxPolicy::nearest() })
        .pack_model("bench-e2e", &layers, &weights)
        .unwrap();
    let mut scalar = ScalarExec::new();
    let mut batch = BatchExec::new();
    let mut systolic = SystolicExec::new();

    // identical outputs before timing (the facade's core guarantee)
    let out_scalar = scalar.run(&model, &input).unwrap();
    let out_batch = batch.run(&model, &input).unwrap();
    let out_sys = systolic.run(&model, &input).unwrap();
    assert_eq!(out_scalar.output, out_batch.output, "e2e paths diverged");
    assert_eq!(out_batch.output, out_sys.output, "systolic path diverged");

    suite.bench("native 3-conv e2e (ScalarExec, port-accurate)", macs as f64, || {
        scalar.run(&model, &input).unwrap().output.data[0]
    });
    suite.bench("native 3-conv e2e (BatchExec, lane-parallel)", macs as f64, || {
        batch.run(&model, &input).unwrap().output.data[0]
    });
    suite.bench("native 3-conv e2e (SystolicExec, array model)", macs as f64, || {
        systolic.run(&model, &input).unwrap().output.data[0]
    });
}

/// `--json PATH`: write the finished suite as a versioned snapshot
/// (the perf-trajectory file `bench-diff` gates against).
fn json_arg() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() {
    let serving_only = std::env::args().any(|a| a == "--serving");
    let coldstart_only = std::env::args().any(|a| a == "--coldstart");
    let network_only = std::env::args().any(|a| a == "--network");
    let daemon_only = std::env::args().any(|a| a == "--daemon");
    let mut suite = BenchSuite::new("e2e");
    if serving_only {
        // Part 3 only (the dedicated CI smoke step); the plain
        // invocation keeps parts 1–2 so the two steps never overlap.
        bench_sharded_serving(&mut suite);
    } else if coldstart_only {
        // Part 4 only: artifact cold-load admission vs repack-from-weights.
        bench_coldstart(&mut suite);
    } else if network_only {
        // Part 5 only: whole-network inference through the
        // NetworkPlan/InferenceSession pipeline on every backend.
        bench_network(&mut suite);
    } else if daemon_only {
        // Part 7 only: the TCP daemon over loopback.
        bench_daemon(&mut suite);
    } else {
        bench_native(&mut suite);
        bench_isa_matrix(&mut suite);
        bench_generations(&mut suite);
        serving(&mut suite);
        // Part 7 rides in the default run too: the perf-trajectory
        // gate snapshots this invocation, so the daemon rows are only
        // gated if they are produced here.
        bench_daemon(&mut suite);
    }
    let results = suite.run();
    if let Some(path) = json_arg() {
        write_snapshot("e2e", &results, &path).unwrap();
    }
}

/// Part 6: the per-bit-width × per-ISA-rung conv matrix — one
/// `conv e2e (BatchExec, {bits}-bit, isa={rung})` row per combination
/// the host supports, plus one port-accurate
/// `conv e2e (ScalarExec, {bits}-bit)` row per width. These rows are
/// the heart of `BENCH_e2e.json`: the trajectory gate watches each
/// rung's p50 independently, so a dispatch-ladder regression (e.g.
/// AVX2 silently falling back to scalar) shows up as a >10% slowdown
/// on exactly one row family. At 6/4 bits the BatchExec rows ride the
/// dense multi-lane packing (ki=2/ki=3 inputs per P word), so they
/// also watch the `p_words_multi` kernels.
///
/// `Isa::set_override` is process-global, but this binary is
/// single-threaded and every rung is bit-exact (asserted before each
/// timing row), so the override only changes speed, never results.
fn bench_isa_matrix(suite: &mut BenchSuite) {
    let mut rng = Rng::new(23);
    for &bits in &[8u32, 6, 4] {
        let lim = 1i64 << (bits - 1);
        let layers = vec![
            ConvLayer::new("m1", 12, 8, 16, 3, 1, 1, 1),
            ConvLayer::new("m2", 12, 16, 16, 3, 1, 1, 1),
        ];
        let weights: Vec<Vec<i64>> = layers
            .iter()
            .map(|l| (0..l.params()).map(|_| rng.range_i64(-lim, lim - 1)).collect())
            .collect();
        let mut input = Tensor3::zeros(layers[0].in_ch, layers[0].in_hw, layers[0].in_hw);
        input.data = (0..input.data.len())
            .map(|_| rng.range_i64(-lim, lim - 1))
            .collect();
        let macs: u64 = layers.iter().map(|l| l.macs()).sum();
        let model = Compiler::for_bits(bits)
            .unwrap()
            .approximate(ApproxPolicy { skip_stats: true, ..ApproxPolicy::nearest() })
            .pack_model("bench-matrix", &layers, &weights)
            .unwrap();
        let mut batch = BatchExec::new();
        Isa::set_override(Some(Isa::Scalar));
        let golden = batch.run(&model, &input).unwrap().output;
        // Port-accurate scalar baseline for this width: one DSP op per
        // packed group on the same dense ki-pixel mapping. Gated
        // bit-exact against the batch golden before timing.
        let mut scalar = ScalarExec::new();
        let out_scalar = scalar.run(&model, &input).unwrap().output;
        assert_eq!(out_scalar, golden, "{bits}-bit ScalarExec diverged");
        suite.bench(
            &format!("conv e2e (ScalarExec, {bits}-bit)"),
            macs as f64,
            || scalar.run(&model, &input).unwrap().output.data[0],
        );
        for isa in Isa::supported() {
            Isa::set_override(Some(isa));
            let out = batch.run(&model, &input).unwrap().output;
            assert_eq!(out, golden, "{bits}-bit ISA rung {} diverged", isa.name());
            suite.bench(
                &format!("conv e2e (BatchExec, {bits}-bit, isa={})", isa.name()),
                macs as f64,
                || batch.run(&model, &input).unwrap().output.data[0],
            );
        }
        Isa::set_override(None);
    }
}

/// Part 8: the packing-generation matrix — one
/// `conv e2e (BatchExec, {bits}-bit, gen={name})` row per generation
/// at 8 and 6 bits, the widths where the overpacked 4-/6-pack carries
/// more slots than the DSP48E1 baseline. Each generation is gated
/// scalar≡batch bit-exact before timing, and the run asserts the
/// acceptance inequality directly: at equal width and identical
/// workload the overpacked generation must issue strictly fewer DSP
/// ops than the baseline. On first capture these rows show up as
/// `added` in `bench-diff` (added rows never fail the gate); they join
/// the committed `BENCH_e2e.json` trajectory at the next snapshot
/// refresh.
fn bench_generations(suite: &mut BenchSuite) {
    let mut rng = Rng::new(29);
    for &bits in &[8u32, 6] {
        let lim = 1i64 << (bits - 1);
        let layers = vec![
            ConvLayer::new("p1", 12, 8, 16, 3, 1, 1, 1),
            ConvLayer::new("p2", 12, 16, 16, 3, 1, 1, 1),
        ];
        let weights: Vec<Vec<i64>> = layers
            .iter()
            .map(|l| (0..l.params()).map(|_| rng.range_i64(-lim, lim - 1)).collect())
            .collect();
        let mut input = Tensor3::zeros(layers[0].in_ch, layers[0].in_hw, layers[0].in_hw);
        input.data = (0..input.data.len())
            .map(|_| rng.range_i64(-lim, lim - 1))
            .collect();
        let macs: u64 = layers.iter().map(|l| l.macs()).sum();
        let mut dsp_ops = std::collections::BTreeMap::new();
        for generation in PackGeneration::ALL {
            let model = Compiler::for_generation(generation, bits)
                .unwrap()
                .approximate(ApproxPolicy { skip_stats: true, ..ApproxPolicy::nearest() })
                .pack_model("bench-gen", &layers, &weights)
                .unwrap();
            let mut scalar = ScalarExec::new();
            let mut batch = BatchExec::new();
            let golden = scalar.run(&model, &input).unwrap();
            let out = batch.run(&model, &input).unwrap();
            assert_eq!(
                out.output, golden.output,
                "{bits}-bit gen={generation}: batch diverged from scalar"
            );
            dsp_ops.insert(generation.name(), out.dsp_ops);
            suite.bench(
                &format!("conv e2e (BatchExec, {bits}-bit, gen={})", generation.name()),
                macs as f64,
                || batch.run(&model, &input).unwrap().output.data[0],
            );
        }
        assert!(
            dsp_ops["overpacked"] < dsp_ops["dsp48e1"],
            "{bits}-bit: overpacked must use strictly fewer DSP ops than the baseline \
             ({} vs {})",
            dsp_ops["overpacked"],
            dsp_ops["dsp48e1"],
        );
        println!(
            "  -> {bits}-bit DSP ops/inference: dsp48e1 {}, overpacked {}, dsp58 {}",
            dsp_ops["dsp48e1"], dsp_ops["overpacked"], dsp_ops["dsp58"]
        );
    }
}

/// Part 5 (`-- --network`, EXPERIMENTS.md §Accuracy): end-to-end
/// whole-network inference (tiny CNN: 3 conv + pool stages + FC head)
/// through `NetworkPlan` + `InferenceSession` on all four executor
/// backends. Outputs are gated bit-identical against the exact scalar
/// reference before any timing, so the rows compare *where* the same
/// arithmetic runs, never *what* it computes.
fn bench_network(suite: &mut BenchSuite) {
    use sdmm::api::{InferenceSession, NetworkPlan, ServingExec};
    use sdmm::coordinator::ServingConfig;

    let model = sdmm::cnn::zoo::tiny_cnn();
    let mut rng = Rng::new(77);
    let conv_w: Vec<Vec<i64>> = model
        .convs
        .iter()
        .map(|l| (0..l.params()).map(|_| rng.range_i64(-128, 127)).collect())
        .collect();
    let fc_w: Vec<Vec<i64>> = model
        .fcs
        .iter()
        .map(|&(i, o)| (0..i * o).map(|_| rng.range_i64(-128, 127)).collect())
        .collect();
    let l0 = &model.convs[0];
    let mut input = Tensor3::zeros(l0.in_ch, l0.in_hw, l0.in_hw);
    input.data = (0..input.data.len()).map(|_| rng.range_i64(-128, 127)).collect();

    let compiler = Compiler::for_bits(8)
        .unwrap()
        .approximate(ApproxPolicy { skip_stats: true, ..ApproxPolicy::nearest() });
    let plan = NetworkPlan::compile(&compiler, "bench-net", &model, &conv_w, &fc_w).unwrap();
    let macs = plan.macs();
    println!(
        "-- network: {} stages + {} FC head(s), {} MACs/inference, {} cached tuples --",
        plan.stages.len(),
        plan.fcs.len(),
        macs,
        plan.cached_tuples()
    );

    let mut scalar = ScalarExec::new();
    let mut batch = BatchExec::new();
    let mut systolic = SystolicExec::new();
    let mut serving = ServingExec::start(ServingConfig {
        shards: 2,
        queue_capacity: 16,
    })
    .unwrap();

    // Bit-exactness gate before timing.
    let golden = plan.reference().forward(&input).unwrap();
    let a = InferenceSession::new(&plan, &mut scalar).infer(&input).unwrap();
    let b = InferenceSession::new(&plan, &mut batch).infer(&input).unwrap();
    let c = InferenceSession::new(&plan, &mut systolic).infer(&input).unwrap();
    let d = InferenceSession::new(&plan, &mut serving).infer(&input).unwrap();
    assert_eq!(a.logits, golden, "scalar network diverged from reference");
    assert_eq!(b, a, "batch network diverged");
    assert_eq!(c, a, "systolic network diverged");
    assert_eq!(d, a, "serving network diverged");

    suite.bench("network e2e (ScalarExec, port-accurate)", macs as f64, || {
        InferenceSession::new(&plan, &mut scalar).infer(&input).unwrap().top1
    });
    suite.bench("network e2e (BatchExec, lane-parallel)", macs as f64, || {
        InferenceSession::new(&plan, &mut batch).infer(&input).unwrap().top1
    });
    suite.bench("network e2e (SystolicExec, array model)", macs as f64, || {
        InferenceSession::new(&plan, &mut systolic).infer(&input).unwrap().top1
    });
    suite.bench("network e2e (ServingExec, 2 shards)", macs as f64, || {
        InferenceSession::new(&plan, &mut serving).infer(&input).unwrap().top1
    });
    let snap = serving.shutdown();
    assert_eq!(snap.total_failed(), 0);
}

/// Part 4 (`-- --coldstart`): registry admission cost, repacking from
/// raw weights vs cold-loading a compiled artifact (WROM stream decode,
/// no re-approximation). Asserts bit-exact serving from the artifact
/// before timing; numbers recorded in EXPERIMENTS.md §Compression.
fn bench_coldstart(suite: &mut BenchSuite) {
    use sdmm::api::CompressionPolicy;

    let layers = vec![
        ConvLayer::new("k1", 16, 8, 24, 3, 1, 1, 1),
        ConvLayer::new("k2", 16, 24, 24, 3, 1, 1, 1),
        ConvLayer::new("k3", 16, 24, 24, 3, 1, 1, 1),
    ];
    let mut rng = Rng::new(91);
    let weights: Vec<Vec<i64>> = layers
        .iter()
        .map(|l| {
            (0..l.params())
                .map(|_| rng.laplace(5.0).round().clamp(-128.0, 127.0) as i64)
                .collect()
        })
        .collect();
    let params: u64 = layers.iter().map(|l| l.params()).sum();
    let compiled = Compiler::for_bits(8)
        .unwrap()
        .approximate(ApproxPolicy { skip_stats: true, ..ApproxPolicy::nearest() })
        .compress(CompressionPolicy::Wrc)
        .pack_model("cold", &layers, &weights)
        .unwrap();
    let dir = std::env::temp_dir().join(format!("sdmm-coldstart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let info = compiled.save(&dir).unwrap();
    println!(
        "-- coldstart: artifact {} bytes, {} WROM entries, stream {} --",
        info.bytes,
        info.wrom_entries,
        info.rate.unwrap()
    );

    // Bit-exactness gate: the cold-loaded registry must serve
    // identically to the in-process-compiled one.
    {
        let warm = ModelRegistry::new();
        warm.register_compiled(&compiled).unwrap();
        let cold = ModelRegistry::new();
        let cold_model = cold.register_from_artifact(&dir).unwrap();
        let sa = SystolicArray::new(SaConfig::paper_prototype(8, PeArch::MultiPack)).unwrap();
        let mut input = Tensor3::zeros(8, 16, 16);
        input.data = (0..input.data.len()).map(|_| rng.range_i64(-128, 127)).collect();
        let a = warm.get(&compiled.key()).unwrap().run(&sa, &input).unwrap();
        let b = cold_model.run(&sa, &input).unwrap();
        assert_eq!(a.output, b.output, "cold-loaded artifact diverged");
    }

    let spec = ModelSpec {
        name: "cold".into(),
        v_bits: 8,
        layers: layers.clone(),
        weights: weights.clone(),
    };
    suite.bench("registry admission: repack from raw weights", params as f64, || {
        ModelRegistry::new().register(spec.clone()).unwrap().cached_tuples()
    });
    suite.bench(
        "registry admission: cold-load artifact (WROM stream decode)",
        params as f64,
        || ModelRegistry::new().register_from_artifact(&dir).unwrap().cached_tuples(),
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Median wall-clock of `n` runs of `f` (seconds).
fn median_secs<R>(n: usize, mut f: impl FnMut() -> R) -> f64 {
    let mut times: Vec<f64> = (0..n)
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[n / 2]
}

/// The mixed-precision model set: one 2-conv model per bit width,
/// identical geometry, weights/inputs drawn in each width's range.
fn mixed_specs() -> Vec<(ModelSpec, Tensor3)> {
    [8u32, 6, 4]
        .iter()
        .map(|&v| {
            let layers = vec![
                ConvLayer::new("s1", 12, 8, 16, 3, 1, 1, 1),
                ConvLayer::new("s2", 12, 16, 16, 3, 1, 1, 1),
            ];
            let spec = ModelSpec::random("mix", v, layers, 100 + v as u64);
            let lim = 1i64 << (v - 1);
            let mut rng = Rng::new(200 + v as u64);
            let mut input = Tensor3::zeros(8, 12, 12);
            input.data = (0..input.data.len())
                .map(|_| rng.range_i64(-lim, lim - 1))
                .collect();
            (spec, input)
        })
        .collect()
}

/// Closed-loop load: keep `conc` requests in flight, round-robin over
/// the model set, until `requests` complete.
fn closed_loop(rt: &ServingRuntime, work: &[(ModelKey, Tensor3)], requests: usize, conc: usize) {
    let mut inflight = VecDeque::new();
    let (mut sent, mut done) = (0usize, 0usize);
    while done < requests {
        while inflight.len() < conc && sent < requests {
            let (key, x) = &work[sent % work.len()];
            match rt.submit(key, x.clone()) {
                Ok(rx) => {
                    inflight.push_back(rx);
                    sent += 1;
                }
                // Backpressure: drain a completion before retrying.
                Err(_) => break,
            }
        }
        if let Some(rx) = inflight.pop_front() {
            rx.recv().unwrap().unwrap();
            done += 1;
        }
    }
}

fn bench_sharded_serving(suite: &mut BenchSuite) {
    // Pin intra-op parallelism so throughput scaling below measures the
    // shards, not the conv tiler grabbing every core for one job.
    std::env::set_var("SDMM_THREADS", "1");
    println!("-- sharded serving (SDMM_THREADS=1, shard-level parallelism only) --");

    let specs = mixed_specs();
    let registry = Arc::new(ModelRegistry::new());
    for (spec, _) in &specs {
        // Compile through the api facade, admit the compiled planes —
        // the registration path every caller shares now.
        let compiled = Compiler::for_bits(spec.v_bits)
            .unwrap()
            .approximate(ApproxPolicy { skip_stats: true, ..ApproxPolicy::nearest() })
            .pack_model(&spec.name, &spec.layers, &spec.weights)
            .unwrap();
        registry.register_compiled(&compiled).unwrap();
    }
    println!(
        "  registry: {} models (8/6/4-bit), {} packed tuples cached once, shared by all shards",
        registry.len(),
        registry.total_cached_tuples()
    );
    let work: Vec<(ModelKey, Tensor3)> =
        specs.iter().map(|(s, x)| (s.key(), x.clone())).collect();

    // Bit-exactness gate before timing: the 4-shard runtime must match
    // the single-shard run_conv_batch reference on every model.
    {
        let rt = ServingRuntime::start(
            Arc::clone(&registry),
            ServingConfig {
                shards: 4,
                queue_capacity: 64,
            },
        )
        .unwrap();
        for (spec, input) in &specs {
            let sa = SystolicArray::new(SaConfig::paper_prototype(
                spec.v_bits,
                PeArch::MultiPack,
            ))
            .unwrap();
            let mut x = input.clone();
            for (layer, w) in spec.layers.iter().zip(&spec.weights) {
                let mut y = sa.run_conv_batch(layer, w, &x).unwrap().output.unwrap();
                relu(&mut y);
                x = requantize(&y, spec.v_bits).0;
            }
            let got = rt.infer(&spec.key(), input.clone()).unwrap();
            assert_eq!(got.output, x, "serving path diverged ({})", spec.key());
        }
        rt.shutdown();
    }

    let fast = std::env::var("SDMM_BENCH_FAST").is_ok();
    let requests = if fast { 18 } else { 72 };
    let reps = if fast { 1 } else { 3 };
    let conc = 8;
    let mut thr = Vec::new();
    for shards in [1usize, 2, 4] {
        let rt = ServingRuntime::start(
            Arc::clone(&registry),
            ServingConfig {
                shards,
                queue_capacity: 64,
            },
        )
        .unwrap();
        closed_loop(&rt, &work, 6, conc); // warm every worker
        suite.bench(
            &format!("serving {shards} shard(s), mixed 8/6/4-bit ({requests} req)"),
            requests as f64,
            || closed_loop(&rt, &work, requests, conc),
        );
        let t = median_secs(reps, || closed_loop(&rt, &work, requests, conc));
        thr.push(requests as f64 / t);
        print!("{}", serving_summary(&rt.snapshot()));
        rt.shutdown();
    }
    println!(
        "  -> serving throughput: 1 shard {:.1}/s, 2 shards {:.1}/s, 4 shards {:.1}/s — \
         scaling 1->4 shards {:.2}x (host parallelism caps the ceiling)",
        thr[0],
        thr[1],
        thr[2],
        thr[2] / thr[0]
    );
}

/// Part 7 (`-- --daemon`, EXPERIMENTS.md §Open-loop serving
/// protocol): the `sdmm serve` TCP daemon measured over loopback from
/// one persistent connection. Two rows: a single interactive-QoS
/// round-trip per iteration (batcher flushes immediately) and a
/// pipelined batch of 16 batch-QoS requests per iteration (one
/// continuous-batching window). Every demo model is served bit-exact
/// against the in-process reference before any timing; the timed
/// loops only spot-check request ids.
fn bench_daemon(suite: &mut BenchSuite) {
    use sdmm::serve::wire::{self, Frame, InferRequest, QosClass};
    use sdmm::serve::{demo_registry, DaemonConfig, ServeDaemon};
    use std::io::Write;
    use std::net::TcpStream;
    use std::time::Duration;

    let registry = Arc::new(ModelRegistry::new());
    let work = demo_registry(&registry).unwrap();
    let daemon = ServeDaemon::start(
        registry,
        ("127.0.0.1", 0),
        DaemonConfig {
            serving: ServingConfig {
                shards: 2,
                queue_capacity: 128,
            },
            batch_window: Duration::from_micros(200),
            max_batch: 16,
            read_timeout: Duration::from_millis(25),
            ..DaemonConfig::default()
        },
    )
    .unwrap();
    let mut s = TcpStream::connect(daemon.local_addr()).unwrap();
    s.set_nodelay(true).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    // Bit-exactness gate before timing: every demo model through the
    // full wire path must match the in-process reference output and
    // op accounting.
    for (i, w) in work.iter().enumerate() {
        let f = Frame::Request(InferRequest {
            request_id: 1_000_000 + i as u64,
            tenant: "bench".into(),
            qos: QosClass::Interactive,
            model: w.key.name.clone(),
            v_bits: w.key.v_bits,
            deadline_us: 0,
            input: w.input.clone(),
        });
        s.write_all(&f.encode()).unwrap();
        match wire::read_frame(&mut s).unwrap() {
            Some(Frame::Response(resp)) => {
                assert_eq!(resp.request_id, 1_000_000 + i as u64);
                assert_eq!(resp.output, w.expected, "daemon diverged ({})", w.key);
                assert_eq!((resp.dsp_ops, resp.mults), (w.dsp_ops, w.mults));
            }
            other => panic!("daemon gate: unexpected frame {other:?}"),
        }
    }

    let wk = &work[0];
    let mut next_id: u64 = 0;
    let mut xchg = |s: &mut TcpStream, n: u64, qos: QosClass| -> u64 {
        let first = next_id;
        let mut buf = Vec::new();
        for _ in 0..n {
            let f = Frame::Request(InferRequest {
                request_id: next_id,
                tenant: "bench".into(),
                qos,
                model: wk.key.name.clone(),
                v_bits: wk.key.v_bits,
                deadline_us: 0,
                input: wk.input.clone(),
            });
            buf.extend_from_slice(&f.encode());
            next_id += 1;
        }
        s.write_all(&buf).unwrap();
        let mut got = 0u64;
        while got < n {
            match wire::read_frame(s).unwrap() {
                Some(Frame::Response(resp)) => {
                    assert!(
                        resp.request_id >= first && resp.request_id < first + n,
                        "daemon bench: stray response id {}",
                        resp.request_id
                    );
                    got += 1;
                }
                other => panic!("daemon bench: unexpected frame {other:?}"),
            }
        }
        got
    };

    suite.bench("daemon round-trip (loopback, interactive QoS)", 1.0, || {
        xchg(&mut s, 1, QosClass::Interactive)
    });
    suite.bench("daemon pipelined x16 (loopback, batch QoS)", 16.0, || {
        xchg(&mut s, 16, QosClass::Batch)
    });

    let stats = daemon.stats();
    println!(
        "  -> daemon: {} requests over {} batches, mean fill {:.1}, 0 corrupt frames asserted",
        stats.requests,
        stats.batches,
        stats.mean_batch_fill()
    );
    assert_eq!(stats.corrupt_frames, 0);
    drop(s);
    let snap = daemon.shutdown();
    assert_eq!(snap.total_failed(), 0, "daemon bench had failed jobs");
}

#[cfg(not(feature = "pjrt"))]
fn serving(_suite: &mut BenchSuite) {
    println!("SKIP e2e serving: built without the `pjrt` feature");
}

#[cfg(feature = "pjrt")]
fn serving(suite: &mut BenchSuite) {
    use sdmm::coordinator::{BatchPolicy, CnnRunner, InferenceServer};
    use sdmm::runtime::{artifacts_available, Artifacts, WeightMode};

    let dir = "artifacts";
    if !artifacts_available(dir) {
        println!("SKIP e2e serving: artifacts/ missing (run `make artifacts`)");
        return;
    }
    let art = Artifacts::load(dir).unwrap();
    let xs = art.f32("eval_x").unwrap();
    let item = 16 * 16;

    for (name, conc) in [("closed-loop c=1", 1usize), ("closed-loop c=64", 64)] {
        let server = InferenceServer::start_factory(
            move || CnnRunner::load("artifacts", WeightMode::Approximated { w_bits: 8 }),
            BatchPolicy::default(),
        );
        // warm the pipeline
        let _ = server.infer(xs[..item].to_vec());
        let requests = if conc == 1 { 64 } else { 512 };
        suite.bench(&format!("{name} ({requests} req)"), requests as f64, || {
            let mut inflight = std::collections::VecDeque::new();
            let (mut sent, mut done) = (0usize, 0usize);
            while done < requests {
                while inflight.len() < conc && sent < requests {
                    let off = (sent * item) % (xs.len() - item);
                    inflight.push_back(server.submit(xs[off..off + item].to_vec()));
                    sent += 1;
                }
                if let Some(rx) = inflight.pop_front() {
                    rx.recv().unwrap().unwrap();
                    done += 1;
                }
            }
            done
        });
        let m = server.shutdown();
        println!(
            "  -> latency p50 {:.2}ms p99 {:.2}ms, occupancy {:.1}%",
            m.latency.p50() / 1e6,
            m.latency.p99() / 1e6,
            m.batch_occupancy(16) * 100.0
        );
    }
}
