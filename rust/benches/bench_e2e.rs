//! End-to-end serving benchmark: the coordinator (dynamic batcher +
//! worker thread + PJRT executable) under closed-loop load — the
//! serving-side headline measurement recorded in EXPERIMENTS.md.
//! Skips (exit 0) when artifacts are missing.

use sdmm::coordinator::{BatchPolicy, CnnRunner, InferenceServer};
use sdmm::runtime::{artifacts_available, Artifacts, WeightMode};
use sdmm::util::bench::BenchSuite;
use std::time::Instant;

fn main() {
    let dir = "artifacts";
    if !artifacts_available(dir) {
        println!("SKIP bench_e2e: artifacts/ missing (run `make artifacts`)");
        return;
    }
    let mut suite = BenchSuite::new("e2e-serving");
    let art = Artifacts::load(dir).unwrap();
    let xs = art.f32("eval_x").unwrap();
    let item = 16 * 16;

    for (name, conc) in [("closed-loop c=1", 1usize), ("closed-loop c=64", 64)] {
        let server = InferenceServer::start_factory(
            move || CnnRunner::load("artifacts", WeightMode::Approximated { w_bits: 8 }),
            BatchPolicy::default(),
        );
        // warm the pipeline
        let _ = server.infer(xs[..item].to_vec());
        let requests = if conc == 1 { 64 } else { 512 };
        suite.bench(&format!("{name} ({requests} req)"), requests as f64, || {
            let mut inflight = std::collections::VecDeque::new();
            let (mut sent, mut done) = (0usize, 0usize);
            while done < requests {
                while inflight.len() < conc && sent < requests {
                    let off = (sent * item) % (xs.len() - item);
                    inflight.push_back(server.submit(xs[off..off + item].to_vec()));
                    sent += 1;
                }
                if let Some(rx) = inflight.pop_front() {
                    rx.recv().unwrap().unwrap();
                    done += 1;
                }
            }
            done
        });
        let wall = Instant::now();
        let m = server.shutdown();
        let _ = wall;
        println!(
            "  -> latency p50 {:.2}ms p99 {:.2}ms, occupancy {:.1}%",
            m.latency.p50() / 1e6,
            m.latency.p99() / 1e6,
            m.batch_occupancy(16) * 100.0
        );
    }

    suite.run();
}
