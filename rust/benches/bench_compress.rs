//! Compression codec throughput (Table 3 pipelines): Huffman encode +
//! decode, pruning, WRC end-to-end.

use sdmm::compress::{huffman_decode, huffman_encode, prune_magnitude, wrc_compress};
use sdmm::packing::Layout;
use sdmm::util::bench::BenchSuite;
use sdmm::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("compress");
    let mut rng = Rng::new(4);
    let n = 65_536usize;
    let ws: Vec<i64> = (0..n)
        .map(|_| rng.laplace(2.0).round().clamp(-128.0, 127.0) as i64)
        .collect();

    suite.bench("huffman encode 64k weights", n as f64, || {
        huffman_encode(&ws).1
    });

    let (bytes, _, book) = huffman_encode(&ws);
    suite.bench("huffman decode 64k weights", n as f64, || {
        huffman_decode(&bytes, ws.len(), &book).unwrap().len()
    });

    suite.bench("prune 64k weights (65%)", n as f64, || {
        prune_magnitude(&ws, 0.65).sparsity
    });

    let layout = Layout::for_bits(8).unwrap();
    suite.bench("wrc full pipeline 64k weights", n as f64, || {
        wrc_compress(&layout, &ws, 0.65).unwrap().wrc.percent()
    });

    suite.run();
}
