//! PJRT runtime: executable load/compile time and per-batch inference
//! latency for the CNN forward and the Pallas SDMM GEMM artifacts.
//! Skips (exit 0) when artifacts are missing or the crate was built
//! without the `pjrt` feature.

fn main() {
    run();
}

#[cfg(not(feature = "pjrt"))]
fn run() {
    println!("SKIP bench_runtime: built without the `pjrt` feature");
}

#[cfg(feature = "pjrt")]
fn run() {
    use sdmm::runtime::{artifacts_available, exec, Artifacts, CnnModel, WeightMode};
    use sdmm::util::bench::BenchSuite;

    let dir = "artifacts";
    if !artifacts_available(dir) {
        println!("SKIP bench_runtime: artifacts/ missing (run `make artifacts`)");
        return;
    }
    let mut suite = BenchSuite::new("runtime");
    let art = Artifacts::load(dir).unwrap();
    let client = exec::Client::cpu().unwrap();

    suite.bench("compile cnn_fwd.hlo.txt", 1.0, || {
        exec::Executable::load(&client, art.hlo_path("cnn_fwd").unwrap()).unwrap()
    });

    let model = CnnModel::load(&client, &art).unwrap();
    let staged = model.stage(WeightMode::Approximated { w_bits: 8 }).unwrap();
    let xs = art.f32("eval_x").unwrap();
    let item = model.input_hw * model.input_hw;
    let x: Vec<f32> = xs[..model.batch * item].to_vec();
    suite.bench("cnn_fwd batch-16 inference", model.batch as f64, || {
        model.infer(&staged, &x).unwrap()
    });

    // the Pallas SDMM GEMM artifact (B=8, K=64, M=48 -> 24576 MACs)
    let gemm = exec::Executable::load(&client, art.hlo_path("sdmm_gemm").unwrap()).unwrap();
    let names = ["gemm_x", "gemm_a_words", "gemm_n", "gemm_s", "gemm_zero", "gemm_neg"];
    let args: Vec<exec::Literal> = names
        .iter()
        .map(|n| {
            exec::literal_i32(&art.i32(n).unwrap(), &art.shape(n).unwrap()).unwrap()
        })
        .collect();
    suite.bench("pallas sdmm_gemm 8x64 @ 48x64", (8 * 64 * 48) as f64, || {
        gemm.execute_i32(&args).unwrap()
    });

    suite.run();
}
