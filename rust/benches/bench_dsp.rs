//! DSP model throughput: raw DSP48E1 ops and full SDMM executions
//! (pack + execute + unpack) per bit width, and the lane-parallel batch
//! engine against the scalar engine on identical work — the simulator's
//! innermost hot path (EXPERIMENTS.md §Perf).

use sdmm::dsp::{BatchEngine, BatchLanes, Dsp48E1, DspOp, PreparedTuple, SdmmEngine};
use sdmm::packing::{pack_approx, Layout};
use sdmm::util::bench::BenchSuite;
use sdmm::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("dsp");
    let mut rng = Rng::new(2);

    let mut dsp = Dsp48E1::new();
    let mut a = 1u64;
    suite.bench("raw dsp48e1 mult-add-c", 1.0, || {
        a = a.wrapping_mul(6364136223846793005).wrapping_add(1);
        dsp.exec(DspOp::MultAddC, a, a >> 32, a >> 16, 0)
    });

    for v in [8u32, 6, 4] {
        let layout = Layout::for_bits(v).unwrap();
        let lim = 1i64 << (v - 1);
        let tuples: Vec<_> = (0..256)
            .map(|_| {
                let ws: Vec<i64> =
                    (0..layout.kw()).map(|_| rng.range_i64(-lim, lim - 1)).collect();
                pack_approx(&layout, &ws).unwrap()
            })
            .collect();
        let inputs: Vec<Vec<i64>> = (0..256)
            .map(|_| (0..layout.ki()).map(|_| rng.range_i64(-lim, lim - 1)).collect())
            .collect();
        let mut engine = SdmmEngine::new();
        let mut i = 0;
        let k = layout.k() as f64;
        suite.bench(
            &format!("sdmm execute {v}-bit ({}x mult/op)", layout.k()),
            k,
            || {
                i = (i + 1) % 256;
                engine.execute(&tuples[i], &inputs[i])
            },
        );

        // pre-packed raw op (no unpack) — the PE datapath alone
        let mut engine2 = SdmmEngine::new();
        let mut j = 0;
        suite.bench(&format!("sdmm execute_raw {v}-bit"), k, || {
            j = (j + 1) % 256;
            engine2.execute_raw(&tuples[j], &inputs[j])
        });

        // batch engine on identical work: one tuple, 256 input groups
        // of P words per call (the scalar comparison point for the
        // EXPERIMENTS.md §Perf table)
        let prepared: Vec<PreparedTuple> = tuples.iter().map(PreparedTuple::prepare).collect();
        let flat: Vec<i64> = inputs.iter().flatten().copied().collect();
        let lanes = BatchLanes::pack(&layout, &flat).unwrap();
        let mut bengine = BatchEngine::new();
        let mut raw = vec![0u64; lanes.groups()];
        let mut ti = 0;
        suite.bench(
            &format!("batch execute_raw {v}-bit (256 groups/call)"),
            k * lanes.groups() as f64,
            || {
                ti = (ti + 1) % 256;
                bengine.execute_raw_batch(&prepared[ti], &lanes, &mut raw);
                raw[0]
            },
        );
    }

    suite.run();
}
