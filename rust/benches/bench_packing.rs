//! Packing-pipeline throughput: manipulation, approximation, tuple
//! packing, WROM interning (the offline compiler's hot path).

use sdmm::manip::{approximate_signed, manipulate};
use sdmm::packing::{pack_approx, Layout, Wrom};
use sdmm::util::bench::BenchSuite;
use sdmm::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("packing");
    let mut rng = Rng::new(1);
    let values: Vec<u64> = (0..4096).map(|_| rng.below(1 << 20) + 1).collect();
    let signed: Vec<i64> = (0..4096).map(|_| rng.range_i64(-128, 127)).collect();

    let mut i = 0;
    suite.bench("manipulate (20-bit values)", 1.0, || {
        i = (i + 1) % values.len();
        manipulate(values[i])
    });

    let mut j = 0;
    suite.bench("approximate_signed (8-bit)", 1.0, || {
        j = (j + 1) % signed.len();
        approximate_signed(signed[j], 8)
    });

    let layout8 = Layout::for_bits(8).unwrap();
    let mut k = 0;
    suite.bench("pack_approx 3x8-bit tuple", 3.0, || {
        k = (k + 3) % (signed.len() - 3);
        pack_approx(&layout8, &signed[k..k + 3]).unwrap()
    });

    let layout4 = Layout::for_bits(4).unwrap();
    let small: Vec<i64> = (0..4096).map(|_| rng.range_i64(-8, 7)).collect();
    let mut k4 = 0;
    suite.bench("pack_approx 2x4-bit tuple", 2.0, || {
        k4 = (k4 + 2) % (small.len() - 2);
        pack_approx(&layout4, &small[k4..k4 + 2]).unwrap()
    });

    // WROM interning at network scale (the Table 3 path)
    suite.bench("wrom compress_stream (4096 weights)", 4096.0, || {
        let mut wrom = Wrom::new(layout8.clone());
        wrom.compress_stream(&signed).unwrap().tuples.len()
    });

    suite.run();
}
