//! Systolic-array simulator throughput: bit-accurate conv execution
//! (simulated MACs/s) and analytic estimates (layers/s) across PE
//! architectures — the Table 4/5 workload.

use sdmm::cnn::infer::Tensor3;
use sdmm::cnn::zoo::{ConvLayer, Model, ModelKind};
use sdmm::sa::{PeArch, SaConfig, SystolicArray};
use sdmm::util::bench::BenchSuite;
use sdmm::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("systolic-array");
    let mut rng = Rng::new(3);

    let layer = ConvLayer::new("bench", 8, 8, 12, 3, 1, 1, 1);
    let weights: Vec<i64> = (0..layer.params()).map(|_| rng.range_i64(-128, 127)).collect();
    let mut input = Tensor3::zeros(layer.in_ch, layer.in_hw, layer.in_hw);
    input.data = (0..input.data.len()).map(|_| rng.range_i64(-128, 127)).collect();
    let macs = layer.macs() as f64;

    for (name, arch, v) in [
        ("run_conv MP 8-bit (bit-accurate)", PeArch::MultiPack, 8u32),
        ("run_conv MP 4-bit (bit-accurate)", PeArch::MultiPack, 4),
        ("run_conv 1M 8-bit (bit-accurate)", PeArch::OneMac, 8),
    ] {
        let lim = 1i64 << (v - 1);
        let w: Vec<i64> = weights.iter().map(|&x| x.clamp(-lim, lim - 1)).collect();
        let inp = Tensor3 {
            c: input.c,
            h: input.h,
            w: input.w,
            data: input.data.iter().map(|&x| x.clamp(-lim, lim - 1)).collect(),
        };
        let sa = SystolicArray::new(SaConfig::paper_prototype(v, arch)).unwrap();
        suite.bench(name, macs, || sa.run_conv(&layer, &w, &inp).unwrap().cycles);
    }

    // analytic estimates over the whole AlexNet (Table-scale workload)
    let model = Model::build(ModelKind::Alexnet);
    let sa = SystolicArray::new(SaConfig::paper_prototype(8, PeArch::MultiPack)).unwrap();
    suite.bench("estimate AlexNet (5 conv layers)", 5.0, || {
        model
            .convs
            .iter()
            .map(|l| sa.estimate_layer(l).cycles)
            .sum::<u64>()
    });

    suite.run();
}
