//! Systolic-array simulator throughput: bit-accurate conv execution
//! (simulated MACs/s) and analytic estimates (layers/s) across PE
//! architectures — the Table 4/5 workload — plus the scalar-vs-batch
//! comparison the perf acceptance gate reads (EXPERIMENTS.md §Perf).

use sdmm::cnn::infer::Tensor3;
use sdmm::cnn::zoo::{ConvLayer, Model, ModelKind};
use sdmm::dsp::Isa;
use sdmm::sa::{PeArch, SaConfig, SystolicArray};
use sdmm::util::bench::{write_snapshot, BenchSuite};
use sdmm::util::rng::Rng;
use std::time::Instant;

/// `--json PATH`: write the finished suite as a versioned snapshot
/// (the perf-trajectory file `bench-diff` gates against).
fn json_arg() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1).cloned())
}

/// Median wall-clock of `n` runs of `f` (seconds).
fn median_secs<R>(n: usize, mut f: impl FnMut() -> R) -> f64 {
    let mut times: Vec<f64> = (0..n)
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[n / 2]
}

fn main() {
    let mut suite = BenchSuite::new("systolic-array");
    let mut rng = Rng::new(3);

    let layer = ConvLayer::new("bench", 8, 8, 12, 3, 1, 1, 1);
    let weights: Vec<i64> = (0..layer.params()).map(|_| rng.range_i64(-128, 127)).collect();
    let mut input = Tensor3::zeros(layer.in_ch, layer.in_hw, layer.in_hw);
    input.data = (0..input.data.len()).map(|_| rng.range_i64(-128, 127)).collect();
    let macs = layer.macs() as f64;

    for (name, arch, v) in [
        ("run_conv MP 8-bit (bit-accurate)", PeArch::MultiPack, 8u32),
        ("run_conv MP 4-bit (bit-accurate)", PeArch::MultiPack, 4),
        ("run_conv 1M 8-bit (bit-accurate)", PeArch::OneMac, 8),
    ] {
        let lim = 1i64 << (v - 1);
        let w: Vec<i64> = weights.iter().map(|&x| x.clamp(-lim, lim - 1)).collect();
        let inp = Tensor3 {
            c: input.c,
            h: input.h,
            w: input.w,
            data: input.data.iter().map(|&x| x.clamp(-lim, lim - 1)).collect(),
        };
        let sa = SystolicArray::new(SaConfig::paper_prototype(v, arch)).unwrap();
        suite.bench(name, macs, || sa.run_conv(&layer, &w, &inp).unwrap().cycles);
        if arch == PeArch::MultiPack {
            suite.bench(
                &format!("run_conv_batch MP {v}-bit (lane-parallel)"),
                macs,
                || sa.run_conv_batch(&layer, &w, &inp).unwrap().cycles,
            );
        }
    }

    // The acceptance comparison: a larger MP layer, scalar engine vs
    // batch engine (packing amortized via the reused plane), identical
    // outputs asserted before timing.
    let big = ConvLayer::new("cmp", 14, 16, 48, 3, 1, 1, 1);
    let w: Vec<i64> = (0..big.params()).map(|_| rng.range_i64(-128, 127)).collect();
    let mut inp = Tensor3::zeros(big.in_ch, big.in_hw, big.in_hw);
    inp.data = (0..inp.data.len()).map(|_| rng.range_i64(-128, 127)).collect();
    let sa = SystolicArray::new(SaConfig::paper_prototype(8, PeArch::MultiPack)).unwrap();
    let plane = sa.pack_plane(&big, &w).unwrap();
    let scalar_run = sa.run_conv(&big, &w, &inp).unwrap();
    let batch_run = sa.run_conv_batch_with_plane(&big, &plane, &inp).unwrap();
    assert_eq!(scalar_run.output, batch_run.output, "paths diverged");
    let big_macs = big.macs() as f64;
    suite.bench("cmp-layer run_conv MP 8-bit (scalar)", big_macs, || {
        sa.run_conv(&big, &w, &inp).unwrap().mults
    });
    suite.bench("cmp-layer run_conv_batch_with_plane MP 8-bit", big_macs, || {
        sa.run_conv_batch_with_plane(&big, &plane, &inp).unwrap().mults
    });
    // Per-ISA-rung rows for the same batch path (trajectory matrix —
    // bit-exactness across rungs is asserted before each timing row).
    for isa in Isa::supported() {
        Isa::set_override(Some(isa));
        let run = sa.run_conv_batch_with_plane(&big, &plane, &inp).unwrap();
        assert_eq!(run.output, batch_run.output, "ISA rung {} diverged", isa.name());
        suite.bench(
            &format!("cmp-layer run_conv_batch MP 8-bit (isa={})", isa.name()),
            big_macs,
            || sa.run_conv_batch_with_plane(&big, &plane, &inp).unwrap().mults,
        );
    }
    Isa::set_override(None);
    // Multi-lane rows: the same cmp layer at 6/4 bits, where the
    // ki=2/ki=3 port layouts pack multiple dense pixels per P word and
    // the batch path runs the `p_words_multi` kernels. Each rung is
    // gated bit-exact against the scalar engine before timing, so these
    // rows watch both the dense packing and the vectorized kernels.
    for v in [6u32, 4] {
        let lim = 1i64 << (v - 1);
        let wv: Vec<i64> = w.iter().map(|&x| x.clamp(-lim, lim - 1)).collect();
        let inpv = Tensor3 {
            c: inp.c,
            h: inp.h,
            w: inp.w,
            data: inp.data.iter().map(|&x| x.clamp(-lim, lim - 1)).collect(),
        };
        let sav = SystolicArray::new(SaConfig::paper_prototype(v, PeArch::MultiPack)).unwrap();
        let planev = sav.pack_plane(&big, &wv).unwrap();
        let golden = sav.run_conv(&big, &wv, &inpv).unwrap();
        for isa in Isa::supported() {
            Isa::set_override(Some(isa));
            let run = sav.run_conv_batch_with_plane(&big, &planev, &inpv).unwrap();
            assert_eq!(
                run.output,
                golden.output,
                "{v}-bit multi-lane ISA rung {} diverged",
                isa.name()
            );
            suite.bench(
                &format!("cmp-layer run_conv_batch MP {v}-bit (isa={})", isa.name()),
                big_macs,
                || sav.run_conv_batch_with_plane(&big, &planev, &inpv).unwrap().mults,
            );
        }
        Isa::set_override(None);
    }
    let reps = if std::env::var("SDMM_BENCH_FAST").is_ok() { 3 } else { 7 };
    let t_scalar = median_secs(reps, || sa.run_conv(&big, &w, &inp).unwrap());
    let t_batch = median_secs(reps, || {
        sa.run_conv_batch_with_plane(&big, &plane, &inp).unwrap()
    });
    println!(
        "  -> cmp layer ({} MACs): scalar {:.2}ms, batch {:.2}ms — speedup {:.2}x \
         (threads: SDMM_THREADS or all cores)",
        big.macs(),
        t_scalar * 1e3,
        t_batch * 1e3,
        t_scalar / t_batch
    );

    // analytic estimates over the whole AlexNet (Table-scale workload)
    let model = Model::build(ModelKind::Alexnet);
    let sa = SystolicArray::new(SaConfig::paper_prototype(8, PeArch::MultiPack)).unwrap();
    suite.bench("estimate AlexNet (5 conv layers)", 5.0, || {
        model
            .convs
            .iter()
            .map(|l| sa.estimate_layer(l).cycles)
            .sum::<u64>()
    });

    let results = suite.run();
    if let Some(path) = json_arg() {
        write_snapshot("systolic-array", &results, &path).unwrap();
    }
}
