//! Network-pipeline property tests (util::check harness — proptest is
//! not in the vendored crate set).
//!
//! Over random zoo-style geometries (2 convs, random pools including
//! odd-dimension floor pooling, optional FC head) × {8, 6, 4}-bit
//! operands × **all** `CompressionPolicy` variants:
//!
//! * `NetworkPlan` output is bit-identical across `ScalarExec`,
//!   `BatchExec`, `SystolicExec` and `ServingExec` — logits, top-1 and
//!   op accounting alike — and equals the exact scalar reference over
//!   the plan's effective weights.
//! * `save → load → serve` of the plan's `CompiledModel` artifacts
//!   preserves outputs bit-exactly (the deployable path changes where
//!   weights live, never what they compute).

use sdmm::api::{
    ApproxPolicy, BatchExec, Compiler, CompressionPolicy, InferenceSession, NetworkPlan,
    ScalarExec, ServingExec, SystolicExec,
};
use sdmm::cnn::infer::Tensor3;
use sdmm::cnn::zoo::{ConvLayer, Model, ModelKind};
use sdmm::coordinator::ServingConfig;
use sdmm::util::check::check;
use sdmm::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};

static PLAN_ID: AtomicUsize = AtomicUsize::new(0);

type Case = (Model, Vec<Vec<i64>>, Vec<Vec<i64>>, Tensor3);

/// Random 2-conv (+ optional FC) network with in-range weights and
/// input at width `v`. Every conv preserves its spatial size (k=3/p=1
/// or k=1/p=0), so the random pool flags alone decide the transitions
/// — including floor pooling of odd sizes (6 → pool → 3 → pool → 1).
fn random_net(r: &mut Rng, v: u32) -> Case {
    let lim = 1i64 << (v - 1);
    let hw0 = 2 * (3 + r.below(2) as usize); // 6 or 8
    let c0 = 1 + r.below(3) as usize;
    let c1 = 1 + r.below(4) as usize;
    let c2 = 1 + r.below(5) as usize;
    let pool0 = r.bool(0.5);
    let hw1 = if pool0 { hw0 / 2 } else { hw0 };
    let k1 = if r.bool(0.5) { 3 } else { 1 };
    let convs = vec![
        ConvLayer::new("p0", hw0, c0, c1, 3, 1, 1, 1),
        ConvLayer::new("p1", hw1, c1, c2, k1, 1, if k1 == 3 { 1 } else { 0 }, 1),
    ];
    let pool1 = hw1 >= 2 && r.bool(0.5);
    let hw2 = if pool1 { hw1 / 2 } else { hw1 };
    let fcs = if r.bool(0.7) {
        vec![(c2 * hw2 * hw2, 2 + r.below(4) as usize)]
    } else {
        vec![]
    };
    let model = Model {
        kind: ModelKind::TinyCnn,
        convs,
        fcs,
    };
    let conv_w: Vec<Vec<i64>> = model
        .convs
        .iter()
        .map(|l| (0..l.params()).map(|_| r.range_i64(-lim, lim - 1)).collect())
        .collect();
    let fc_w: Vec<Vec<i64>> = model
        .fcs
        .iter()
        .map(|&(i, o)| (0..i * o).map(|_| r.range_i64(-lim, lim - 1)).collect())
        .collect();
    let mut input = Tensor3::zeros(c0, hw0, hw0);
    input.data = (0..input.data.len()).map(|_| r.range_i64(-lim, lim - 1)).collect();
    (model, conv_w, fc_w, input)
}

fn compile(v: u32, policy: CompressionPolicy, case: &Case) -> Result<NetworkPlan, sdmm::error::SdmmError> {
    let (model, cw, fw, _) = case;
    let name = format!("prop{}", PLAN_ID.fetch_add(1, Ordering::Relaxed));
    NetworkPlan::compile(
        &Compiler::for_bits(v)?
            .approximate(ApproxPolicy::nearest())
            .compress(policy),
        &name,
        model,
        cw,
        fw,
    )
}

const ALL_POLICIES: [CompressionPolicy; 4] = [
    CompressionPolicy::None,
    CompressionPolicy::Wrc,
    CompressionPolicy::WrcHuffman,
    CompressionPolicy::PruneWrcHuffman,
];

#[test]
fn prop_network_bit_identical_across_backends() {
    let mut serving = ServingExec::start(ServingConfig {
        shards: 2,
        queue_capacity: 16,
    })
    .unwrap();
    for v in [8u32, 6, 4] {
        for policy in ALL_POLICIES {
            let mut scalar = ScalarExec::new();
            let mut batch = BatchExec::new();
            let mut systolic = SystolicExec::new();
            check(
                "network-bit-identical",
                4,
                9100 + v as u64 * 10 + policy.tag() as u64,
                |r| random_net(r, v),
                |case| {
                    let plan = compile(v, policy, case)?;
                    let input = &case.3;
                    let a = InferenceSession::new(&plan, &mut scalar).infer(input)?;
                    let b = InferenceSession::new(&plan, &mut batch).infer(input)?;
                    let c = InferenceSession::new(&plan, &mut systolic).infer(input)?;
                    let d = InferenceSession::new(&plan, &mut serving).infer(input)?;
                    for (name, out) in
                        [("batch", &b), ("systolic", &c), ("serving", &d)]
                    {
                        if *out != a {
                            return Err(format!(
                                "{name} diverged from scalar (v={v}, {policy}): \
                                 {out:?} vs {a:?}"
                            )
                            .into());
                        }
                    }
                    let golden = plan.reference().forward(input)?;
                    if a.logits != golden {
                        return Err(format!(
                            "scalar != exact reference (v={v}, {policy})"
                        )
                        .into());
                    }
                    if a.mults != plan.macs() {
                        return Err(format!(
                            "mults {} != plan macs {} (v={v}, {policy})",
                            a.mults,
                            plan.macs()
                        )
                        .into());
                    }
                    Ok(())
                },
            );
        }
    }
    let snap = serving.shutdown();
    assert_eq!(snap.total_failed(), 0);
    assert!(snap.total_jobs() > 0);
}

#[test]
fn prop_save_load_serve_preserves_outputs() {
    let mut serving = ServingExec::start(ServingConfig {
        shards: 1,
        queue_capacity: 8,
    })
    .unwrap();
    let base = std::env::temp_dir().join(format!("sdmm-prop-plan-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    for v in [8u32, 6, 4] {
        for policy in ALL_POLICIES {
            let mut batch = BatchExec::new();
            check(
                "network-save-load-serve",
                2,
                9500 + v as u64 * 10 + policy.tag() as u64,
                |r| random_net(r, v),
                |case| {
                    let plan = compile(v, policy, case)?;
                    let input = &case.3;
                    let want = InferenceSession::new(&plan, &mut batch).infer(input)?;
                    let dir = base.join(format!(
                        "{}-{v}-{}",
                        PLAN_ID.fetch_add(1, Ordering::Relaxed),
                        policy.tag()
                    ));
                    plan.save(&dir)?;
                    let loaded = NetworkPlan::load(&dir)?;
                    let _ = std::fs::remove_dir_all(&dir);
                    if loaded.compression != policy || loaded.v_bits != v {
                        return Err("loaded plan metadata diverged".into());
                    }
                    let got = InferenceSession::new(&loaded, &mut batch).infer(input)?;
                    if got != want {
                        return Err(format!(
                            "cold-loaded plan diverged on batch (v={v}, {policy})"
                        )
                        .into());
                    }
                    let served = InferenceSession::new(&loaded, &mut serving).infer(input)?;
                    if served != want {
                        return Err(format!(
                            "cold-loaded plan diverged when served (v={v}, {policy})"
                        )
                        .into());
                    }
                    Ok(())
                },
            );
        }
    }
    let _ = std::fs::remove_dir_all(&base);
    let snap = serving.shutdown();
    assert_eq!(snap.total_failed(), 0);
}
