//! Cross-generation conformance suite.
//!
//! Three legs:
//!
//! 1. Every packing generation (DSP48E1 baseline, overpacked, DSP58
//!    wide-pack) runs on all four `Executor` backends with bit-identical
//!    outputs and op accounting; product-exact generations additionally
//!    agree with the integer convolution reference over the plane's
//!    effective weights.
//! 2. The DSP58 generation replays the checked-in golden network
//!    vectors bit-for-bit on all four backends: it shares the
//!    baseline's 3-bit MW approximation and its layouts are exact, so
//!    anything but identical logits is a packing defect.
//! 3. The `Layout` construction / `b_word` packing surface is total:
//!    arbitrary constructor arguments, arbitrary hand-assembled
//!    layouts and arbitrary inputs come back as `Ok` or a typed
//!    `SdmmError` — never a panic.

mod common;

use common::{compile_plan_gen, load_fixture};
use sdmm::api::{
    ApproxPolicy, BatchExec, CompiledModel, Compiler, CompressionPolicy, Executor,
    InferenceSession, ScalarExec, ServingExec, SystolicExec,
};
use sdmm::cnn::infer::{conv2d_int, relu, requantize, Tensor3};
use sdmm::cnn::zoo::ConvLayer;
use sdmm::coordinator::ServingConfig;
use sdmm::dsp::PackGeneration;
use sdmm::packing::{pack_approx, Layout};
use sdmm::util::check::check;
use sdmm::util::rng::Rng;

fn compile_gen(
    generation: PackGeneration,
    layer: &ConvLayer,
    weights: &[i64],
    v: u32,
) -> CompiledModel {
    Compiler::for_generation(generation, v)
        .unwrap()
        .approximate(ApproxPolicy::nearest())
        .pack_model("gen-conf", &[layer.clone()], &[weights.to_vec()])
        .unwrap()
}

/// Seeded layer + weights + input at width `v`.
fn seeded_case(seed: u64, v: u32) -> (ConvLayer, Vec<i64>, Tensor3) {
    let layer = ConvLayer::new("p", 6, 3, 5, 3, 1, 1, 1);
    let lim = 1i64 << (v - 1);
    let mut rng = Rng::new(seed);
    let weights: Vec<i64> =
        (0..layer.params()).map(|_| rng.range_i64(-lim, lim - 1)).collect();
    let mut input = Tensor3::zeros(3, 6, 6);
    input.data = (0..input.data.len()).map(|_| rng.range_i64(-lim, lim - 1)).collect();
    (layer, weights, input)
}

#[test]
fn all_backends_agree_on_every_generation() {
    let mut serving = ServingExec::start(ServingConfig {
        shards: 2,
        queue_capacity: 16,
    })
    .unwrap();
    for generation in PackGeneration::ALL {
        for v in [8u32, 6, 4] {
            let (layer, weights, input) = seeded_case(500 + v as u64 + 100 * generation.tag() as u64, v);
            let model = compile_gen(generation, &layer, &weights, v);
            let a = ScalarExec::new().run(&model, &input).unwrap();
            let b = BatchExec::new().run(&model, &input).unwrap();
            let c = SystolicExec::new().run(&model, &input).unwrap();
            let d = serving.run(&model, &input).unwrap();
            for (name, out) in [("batch", &b), ("systolic", &c), ("serving", &d)] {
                assert_eq!(
                    a.output, out.output,
                    "scalar vs {name} diverged ({generation} v={v})"
                );
                assert_eq!(
                    (a.dsp_ops, a.mults),
                    (out.dsp_ops, out.mults),
                    "op accounting diverged vs {name} ({generation} v={v})"
                );
            }
            assert_eq!(a.mults, layer.macs(), "{generation} v={v}");
            assert!(a.dsp_ops < a.mults, "{generation} v={v}: no packing gain");
            let layout = &model.layers[0].plane.layout;
            if layout.product_exact() {
                let eff = model.layers[0].effective_weights();
                let mut want = conv2d_int(&input, &eff, &layer);
                relu(&mut want);
                let want = requantize(&want, v).0;
                assert_eq!(
                    a.output, want,
                    "{generation} v={v}: exact generation drifted from the integer reference"
                );
            }
        }
    }
    let snap = serving.shutdown();
    assert_eq!(snap.total_failed(), 0);
}

#[test]
fn overpacked_beats_baseline_dsp_ops_at_equal_width() {
    // The acceptance bar for the overpacked generation: strictly more
    // multiplications per DSP op than the baseline at the same bit
    // width (4 vs 3 at 8 bit, 6 vs 4 at 6 bit), i.e. strictly fewer
    // DSP ops for an identical workload.
    for v in [8u32, 6] {
        let (layer, weights, input) = seeded_case(900 + v as u64, v);
        let base = BatchExec::new()
            .run(&compile_gen(PackGeneration::Dsp48E1, &layer, &weights, v), &input)
            .unwrap();
        let over = BatchExec::new()
            .run(&compile_gen(PackGeneration::Overpacked, &layer, &weights, v), &input)
            .unwrap();
        assert_eq!(base.mults, over.mults, "v={v}: workloads differ");
        assert!(
            over.dsp_ops < base.dsp_ops,
            "v={v}: overpacked used {} DSP ops, baseline {}",
            over.dsp_ops,
            base.dsp_ops
        );
    }
}

#[test]
fn dsp58_replays_golden_vectors_on_all_backends() {
    for bits in [8u32, 6, 4] {
        let fx = load_fixture(bits);
        let plan = compile_plan_gen(
            PackGeneration::Dsp58,
            bits,
            &fx.model,
            &fx.conv_weights,
            &fx.fc_weights,
            &format!("dsp58-golden{bits}"),
            CompressionPolicy::None,
        );
        let mut scalar = ScalarExec::new();
        let mut batch = BatchExec::new();
        let mut systolic = SystolicExec::new();
        let mut serving = ServingExec::start(ServingConfig {
            shards: 2,
            queue_capacity: 16,
        })
        .unwrap();
        {
            let execs: [&mut dyn Executor; 4] =
                [&mut scalar, &mut batch, &mut systolic, &mut serving];
            for e in execs {
                let name = e.name();
                let (out, trace) =
                    InferenceSession::new(&plan, e).infer_trace(&fx.input).unwrap();
                assert_eq!(
                    out.logits, fx.logits,
                    "dsp58/{name} logits != golden (net{bits})"
                );
                assert_eq!(out.top1, fx.top1, "dsp58/{name} top1 != golden (net{bits})");
                for (i, (got, want)) in trace.iter().zip(&fx.stages).enumerate() {
                    assert_eq!(got, want, "dsp58/{name} stage {i} != golden (net{bits})");
                }
            }
        }
        let snap = serving.shutdown();
        assert_eq!(snap.total_failed(), 0);
    }
}

#[test]
fn layout_constructors_are_total() {
    // Constructor grid: every (generation, c, v) pair either yields a
    // layout that re-validates or a typed error — no panics anywhere,
    // including degenerate widths 0 and 1.
    for g in PackGeneration::ALL {
        for c in 0..=20u32 {
            for v in 0..=20u32 {
                if let Ok(l) = Layout::for_generation_wc(g, c, v) {
                    l.validate().unwrap();
                }
            }
        }
    }
}

#[test]
fn prop_hand_assembled_layouts_never_panic() {
    // Layout fields are public (hand-assembled custom layouts are
    // supported); validate() must be total over arbitrary field values,
    // including empty offset vectors and saturating-size offsets.
    check(
        "layout-validate-total",
        4000,
        7701,
        |r| {
            let offsets = |r: &mut Rng| -> Vec<u32> {
                let n = r.below(4) as usize; // 0..=3, 0 hits the empty path
                (0..n)
                    .map(|_| {
                        if r.bool(0.1) {
                            u32::MAX - r.below(4) as u32
                        } else {
                            r.below(50) as u32
                        }
                    })
                    .collect()
            };
            Layout {
                v: r.below(20) as u32,
                c: r.below(20) as u32,
                a_offsets: offsets(r),
                b_offsets: offsets(r),
                slot_width: r.below(40) as u32,
                generation: PackGeneration::ALL[r.below(3) as usize],
                trunc: r.below(8) as u32,
                mw_bits: r.below(6) as u32,
            }
        },
        |l| {
            // Either verdict is fine; returning at all is the property.
            let _ = l.validate();
            Ok(())
        },
    );
}

#[test]
fn prop_b_word_and_pack_are_total_on_valid_layouts() {
    // On every shipped layout, b_word and pack_approx over arbitrary
    // (wrong-arity, out-of-range) operands return Ok or a typed error.
    let layouts: Vec<Layout> = PackGeneration::ALL
        .iter()
        .flat_map(|&g| [8u32, 6, 4].map(|v| Layout::for_generation(g, v).unwrap()))
        .collect();
    check(
        "b-word-pack-total",
        4000,
        7702,
        |r| {
            let li = r.below(layouts.len() as u64) as usize;
            let n_inputs = r.below(5) as usize;
            let inputs: Vec<i64> = (0..n_inputs).map(|_| r.range_i64(-400, 400)).collect();
            let n_weights = r.below(5) as usize;
            let weights: Vec<i64> = (0..n_weights).map(|_| r.range_i64(-400, 400)).collect();
            (li, inputs, weights)
        },
        |(li, inputs, weights)| {
            let l = &layouts[*li];
            let _ = l.b_word(inputs);
            if let Ok(t) = pack_approx(l, weights) {
                // A packed tuple must accept exactly the layout's arity
                // and refuse everything else with a typed error.
                let _ = t.values();
                assert_eq!(l.b_word(&vec![0i64; l.ki()]).unwrap_or(1), 0);
            }
            Ok(())
        },
    );
}
