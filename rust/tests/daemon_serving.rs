//! End-to-end tests for the `sdmm serve` TCP daemon (DESIGN.md §12):
//! open-loop round trips through the real socket stack, the seeded
//! wire-protocol mutation sweep, tenant-quota admission, and chaos
//! replays proving continuous batching stays bit-exact and
//! exactly-once while shards panic, stall and degrade underneath it.

use sdmm::coordinator::{
    ModelRegistry, ServingConfig, ServingRuntime, SubmitOptions, SupervisionPolicy,
};
use sdmm::fault::{frame_faults, FaultPlan, FaultSpec};
use sdmm::serve::loadgen::{self, LoadgenConfig, TraceKind};
use sdmm::serve::wire::{self, ErrorCode, Frame, InferRequest, QosClass};
use sdmm::serve::{demo_registry, DaemonConfig, DemoWork, ServeDaemon};
use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Fixed replay seeds, same contract as `tests/chaos_serving.rs`:
/// `SDMM_CHAOS_SEED` overrides the set for targeted replays.
fn chaos_seeds() -> Vec<u64> {
    match std::env::var("SDMM_CHAOS_SEED") {
        Ok(v) => vec![v.parse().expect("SDMM_CHAOS_SEED must be a u64")],
        Err(_) => vec![7, 42, 0xC0FFEE],
    }
}

fn test_daemon(config: DaemonConfig) -> (ServeDaemon, Vec<DemoWork>) {
    let registry = Arc::new(ModelRegistry::new());
    let work = demo_registry(&registry).expect("demo registry");
    let daemon = ServeDaemon::start(registry, ("127.0.0.1", 0), config).expect("daemon start");
    (daemon, work)
}

fn request_frame(wk: &DemoWork, request_id: u64, qos: QosClass, deadline_us: u64) -> Vec<u8> {
    Frame::Request(InferRequest {
        request_id,
        tenant: "tenant-0".into(),
        qos,
        model: wk.key.name.clone(),
        v_bits: wk.key.v_bits,
        deadline_us,
        input: wk.input.clone(),
    })
    .encode()
}

#[test]
fn open_loop_round_trip_is_clean_and_bit_exact() {
    let (daemon, work) = test_daemon(DaemonConfig {
        serving: ServingConfig {
            shards: 3,
            queue_capacity: 128,
        },
        // Big enough that a slow CI runner can't push a tenant to its
        // bound mid-run (the quota path has its own dedicated test).
        tenant_quota: 4096,
        read_timeout: Duration::from_millis(25),
        ..DaemonConfig::default()
    });
    let cfg = LoadgenConfig {
        addr: daemon.local_addr(),
        connections: 8,
        requests: 1200,
        rate_per_sec: 24_000.0,
        trace: TraceKind::Poisson,
        seed: 42,
        tenants: 4,
        interactive_pct: 10,
        deadline: None,
        recv_grace: Duration::from_secs(30),
        verify: true,
    };
    let report = loadgen::run(&cfg, &work).expect("loadgen run");
    assert!(report.clean(), "dirty run:\n{}", report.render());
    assert_eq!(report.sent, 1200);
    assert_eq!(report.ok, 1200);
    let stats = daemon.stats();
    assert_eq!(stats.requests, 1200);
    assert_eq!(stats.corrupt_frames, 0);
    assert_eq!(stats.quota_refusals, 0);
    assert!(stats.batches > 0, "continuous batcher never flushed");
    assert!(
        stats.mean_batch_fill() >= 1.0,
        "fill {:.2}",
        stats.mean_batch_fill()
    );
    let snap = daemon.shutdown();
    assert_eq!(snap.total_jobs(), 1200);
    assert_eq!(snap.total_failed(), 0);
    assert!(snap.healthy(), "daemon left shards unhealthy");
}

#[test]
fn wire_mutation_sweep_yields_only_typed_refusals() {
    let (daemon, work) = test_daemon(DaemonConfig {
        serving: ServingConfig {
            shards: 2,
            queue_capacity: 64,
        },
        batch_window: Duration::from_micros(300),
        read_timeout: Duration::from_millis(25),
        ..DaemonConfig::default()
    });
    let addr = daemon.local_addr();
    let template = request_frame(&work[0], 7, QosClass::Batch, 0);
    let faults = frame_faults(0x0D15_EA5E, 256);
    assert_eq!(faults.len(), 256);
    let (mut corrupt, mut admission, mut deadline_errs) = (0u32, 0u32, 0u32);
    for (fi, fault) in faults.iter().enumerate() {
        let mutated = wire::mutate_frame(&template, fault);
        let mut s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_millis(250))).unwrap();
        s.write_all(&mutated).expect("send mutated frame");
        // Half-close so a truncated frame reads as EOF-mid-frame on
        // the daemon instead of a stalled peer.
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let hang_guard = Instant::now() + Duration::from_secs(10);
        loop {
            match wire::read_frame(&mut s) {
                Ok(Some(Frame::Error(e))) => match e.code {
                    ErrorCode::CorruptFrame => corrupt += 1,
                    ErrorCode::Admission => admission += 1,
                    ErrorCode::Deadline => deadline_errs += 1,
                    other => panic!(
                        "fault {fi} ({fault:?}): untyped refusal {other:?}: {}",
                        e.message
                    ),
                },
                Ok(Some(f)) => panic!(
                    "fault {fi} ({fault:?}): daemon answered a corrupted frame with {}",
                    f.kind()
                ),
                Ok(None) => break,
                Err(e) if wire::is_timeout(&e) => {
                    assert!(
                        Instant::now() < hang_guard,
                        "fault {fi} ({fault:?}): daemon hung"
                    );
                }
                Err(_) => break, // refusal-by-close is acceptable
            }
        }
    }
    // The sweep must exercise every refusal category: framing/decoder
    // (flips, truncations, resealed layout lies), admission (resealed
    // unknown-model / bit-width lies), and deadline (resealed 1 us
    // budgets).
    assert!(corrupt > 0, "sweep never produced a CorruptFrame refusal");
    assert!(admission > 0, "sweep never produced an Admission refusal");
    assert!(deadline_errs > 0, "sweep never produced a Deadline refusal");
    let stats = daemon.stats();
    assert!(
        stats.corrupt_frames > 0,
        "daemon counted no corrupt frames: {stats:?}"
    );

    // The daemon must still serve a pristine request after the sweep.
    let mut s = TcpStream::connect(addr).expect("reconnect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(&request_frame(&work[0], 99, QosClass::Interactive, 0))
        .unwrap();
    match wire::read_frame(&mut s).expect("post-sweep response") {
        Some(Frame::Response(resp)) => {
            assert_eq!(resp.request_id, 99);
            assert_eq!(resp.output, work[0].expected, "post-sweep response not bit-exact");
        }
        other => panic!("post-sweep request not served: {other:?}"),
    }
    drop(s);
    let snap = daemon.shutdown();
    assert!(snap.healthy(), "mutation sweep damaged shard health");
}

#[test]
fn tenant_quota_refuses_typed_and_releases() {
    let (daemon, work) = test_daemon(DaemonConfig {
        serving: ServingConfig {
            shards: 1,
            queue_capacity: 64,
        },
        tenant_quota: 1,
        // Hold the batch so the first request keeps its quota slot
        // while the rest arrive.
        batch_window: Duration::from_millis(100),
        max_batch: 1024,
        read_timeout: Duration::from_millis(25),
        ..DaemonConfig::default()
    });
    let addr = daemon.local_addr();
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let n = 16u64;
    for id in 0..n {
        s.write_all(&request_frame(&work[0], id, QosClass::Batch, 0))
            .unwrap();
    }
    let (mut ok, mut refused) = (0u64, 0u64);
    for _ in 0..n {
        match wire::read_frame(&mut s).expect("quota response") {
            Some(Frame::Response(resp)) => {
                assert_eq!(resp.request_id, 0, "only the slot holder may succeed");
                assert_eq!(resp.output, work[0].expected);
                ok += 1;
            }
            Some(Frame::Error(e)) => {
                assert_eq!(e.code, ErrorCode::Admission, "{}", e.message);
                assert!(
                    e.message.contains("quota"),
                    "refusal should name the quota: {}",
                    e.message
                );
                refused += 1;
            }
            other => panic!("unexpected quota-phase frame: {other:?}"),
        }
    }
    assert_eq!((ok, refused), (1, n - 1));
    // The slot was released when request 0 resolved — the tenant can
    // submit again.
    s.write_all(&request_frame(&work[0], 77, QosClass::Interactive, 0))
        .unwrap();
    match wire::read_frame(&mut s).expect("post-release response") {
        Some(Frame::Response(resp)) => assert_eq!(resp.request_id, 77),
        other => panic!("quota slot never released: {other:?}"),
    }
    assert_eq!(daemon.stats().quota_refusals, n - 1);
    drop(s);
    let snap = daemon.shutdown();
    assert!(snap.healthy());
}

#[test]
fn chaos_daemon_stays_bit_exact_and_exactly_once() {
    for seed in chaos_seeds() {
        let shards = 3usize;
        let n = 90usize;
        let registry = Arc::new(ModelRegistry::new());
        let work = demo_registry(&registry).expect("demo registry");

        // Reference: sequential submit_with on a fault-free runtime
        // over the same registry (and a cross-check against the demo
        // ground truth, which came through ServingExec).
        let ref_rt = ServingRuntime::start(
            Arc::clone(&registry),
            ServingConfig {
                shards: 2,
                queue_capacity: 64,
            },
        )
        .expect("reference runtime");
        let mut refs = Vec::new();
        for wk in &work {
            let rx = ref_rt
                .submit_with(&wk.key, wk.input.clone(), SubmitOptions::default())
                .expect("reference admit");
            let out = rx.recv().expect("reference resolve").expect("reference ok");
            assert_eq!(out.output, wk.expected, "reference diverged from demo ground truth");
            refs.push(out.output);
        }
        ref_rt.shutdown();

        // Daemon under a deterministic fault plan: worker panics,
        // latency spikes, queue stalls, forced scalar degradations.
        let plan = FaultPlan::generate(seed, &FaultSpec::light(shards, (n / shards) as u64));
        let policy = SupervisionPolicy {
            max_restarts: 8,
            initial_backoff: Duration::from_micros(200),
            max_backoff: Duration::from_millis(2),
            default_retry_budget: (plan.panics() as u32).max(2),
        };
        let daemon = ServeDaemon::start(
            Arc::clone(&registry),
            ("127.0.0.1", 0),
            DaemonConfig {
                serving: ServingConfig {
                    shards,
                    queue_capacity: 64,
                },
                policy,
                batch_window: Duration::from_micros(300),
                max_batch: 16,
                tenant_quota: 0, // quotas off: every request must execute
                read_timeout: Duration::from_millis(25),
                fault_plan: Some(plan),
                ..DaemonConfig::default()
            },
        )
        .expect("chaos daemon start");

        // One pipelined connection: send everything, then demand each
        // id resolves exactly once, bit-exact vs the sequential
        // reference (degraded scalar-tier answers included).
        let mut s = TcpStream::connect(daemon.local_addr()).expect("connect");
        s.set_read_timeout(Some(Duration::from_millis(250))).unwrap();
        for i in 0..n {
            let qos = if i % 5 == 0 {
                QosClass::Interactive
            } else {
                QosClass::Batch
            };
            s.write_all(&request_frame(&work[i % work.len()], i as u64, qos, 0))
                .unwrap();
        }
        let mut seen = vec![false; n];
        let mut resolved = 0usize;
        let hang_guard = Instant::now() + Duration::from_secs(60);
        while resolved < n {
            match wire::read_frame(&mut s) {
                Ok(Some(Frame::Response(resp))) => {
                    let i = resp.request_id as usize;
                    assert!(i < n, "seed {seed}: unknown id {i}");
                    assert!(!seen[i], "seed {seed}: request {i} answered twice");
                    seen[i] = true;
                    resolved += 1;
                    assert_eq!(
                        resp.output,
                        refs[i % refs.len()],
                        "seed {seed}: request {i} not bit-exact (degraded={})",
                        resp.degraded
                    );
                }
                Ok(Some(Frame::Error(e))) => panic!(
                    "seed {seed}: typed failure leaked through the retry budget: {} ({:?})",
                    e.message, e.code
                ),
                Ok(Some(f)) => panic!("seed {seed}: unexpected {} frame", f.kind()),
                Ok(None) => panic!("seed {seed}: daemon closed with {resolved}/{n} resolved"),
                Err(e) if wire::is_timeout(&e) => {
                    assert!(
                        Instant::now() < hang_guard,
                        "seed {seed}: hung with {resolved}/{n} resolved"
                    );
                }
                Err(e) => panic!("seed {seed}: read failed: {e}"),
            }
        }
        assert!(seen.iter().all(|&b| b), "seed {seed}: not every id resolved");
        // Graceful drain on the same connection.
        s.write_all(&Frame::Shutdown.encode()).unwrap();
        let ack_guard = Instant::now() + Duration::from_secs(10);
        loop {
            match wire::read_frame(&mut s) {
                Ok(Some(Frame::ShutdownAck)) | Ok(None) => break,
                Ok(Some(f)) => panic!("seed {seed}: {} after shutdown", f.kind()),
                Err(e) if wire::is_timeout(&e) => {
                    assert!(Instant::now() < ack_guard, "seed {seed}: shutdown hung");
                }
                Err(e) => panic!("seed {seed}: shutdown read failed: {e}"),
            }
        }
        let snap = daemon.shutdown();
        assert!(
            snap.healthy(),
            "seed {seed}: shards not healthy after chaos: {}",
            sdmm::report::serving_summary(&snap)
        );
        assert!(
            snap.total_jobs() as usize >= n,
            "seed {seed}: {} jobs recorded for {n} requests",
            snap.total_jobs()
        );
    }
}
