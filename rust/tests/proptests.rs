//! Property tests (util::check harness — proptest is not vendored).
//! Each property runs hundreds of randomized cases with a fixed seed;
//! failures print the reproducing input.

use sdmm::dsp::SdmmEngine;
use sdmm::manip::{approximate_signed, manipulate};
use sdmm::packing::{bray_curtis, fine_tune_tuple, is_feasible_exact, pack_approx, Layout};
use sdmm::util::check::check;

#[test]
fn prop_manipulation_is_exact_decomposition() {
    check(
        "manipulate-round-trip",
        5000,
        101,
        |r| r.below((1 << 24) - 1) + 1,
        |&w| {
            let m = manipulate(w);
            if m.value() == w && (m.mw == 0 || m.mw % 2 == 1) {
                Ok(())
            } else {
                Err(format!("{m:?} != {w}").into())
            }
        },
    );
}

#[test]
fn prop_approximation_minimizes_distance() {
    // the chosen representable value is at least as close as any
    // random competitor of the constrained form
    check(
        "approx-is-nearest",
        2000,
        102,
        |r| {
            (
                r.range_i64(1, 128) as u64,
                r.below(5),
                r.below(8) as u32,
                r.below(8) as u32,
            )
        },
        |&(mag, mw_idx, n, s)| {
            let a = sdmm::manip::approximate(mag, 128);
            let mw = sdmm::manip::APPROX_MW[mw_idx as usize] as u64;
            let competitor = (1 + (mw << n)) << s;
            if competitor <= 128 && competitor.abs_diff(mag) < a.abs_error() {
                Err(format!("{competitor} closer to {mag} than {}", a.approx).into())
            } else {
                Ok(())
            }
        },
    );
}

#[test]
fn prop_sdmm_identity_8bit() {
    let layout = Layout::for_bits(8).unwrap();
    let mut engine = SdmmEngine::new();
    check(
        "sdmm-8bit-identity",
        8000,
        103,
        |r| {
            (
                [
                    r.range_i64(-128, 127),
                    r.range_i64(-128, 127),
                    r.range_i64(-128, 127),
                ],
                r.range_i64(-128, 127),
            )
        },
        |&(ws, i)| {
            let t = pack_approx(&layout, &ws)?;
            let got = t.unpack_all(engine.execute_raw(&t, &[i]), &[i]);
            let want = t.expected_products(&[i]);
            if got == want {
                Ok(())
            } else {
                Err(format!("{got:?} != {want:?}").into())
            }
        },
    );
}

#[test]
fn prop_sdmm_identity_multi_input() {
    for v in [6u32, 4] {
        let layout = Layout::for_bits(v).unwrap();
        let lim = 1i64 << (v - 1);
        let mut engine = SdmmEngine::new();
        let ki = layout.ki();
        let kw = layout.kw();
        check(
            "sdmm-multi-input-identity",
            6000,
            104 + v as u64,
            |r| {
                let ws: Vec<i64> = (0..kw).map(|_| r.range_i64(-lim, lim - 1)).collect();
                let is: Vec<i64> = (0..ki).map(|_| r.range_i64(-lim, lim - 1)).collect();
                (ws, is)
            },
            |(ws, is)| {
                let t = pack_approx(&layout, ws)?;
                let got = t.unpack_all(engine.execute_raw(&t, is), is);
                let want = t.expected_products(is);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("{got:?} != {want:?}").into())
                }
            },
        );
    }
}

#[test]
fn prop_fine_tuning_produces_feasible_nearby_tuples() {
    let layout = Layout::for_bits(8).unwrap();
    check(
        "fine-tune-feasible",
        400,
        105,
        |r| {
            vec![
                r.range_i64(-128, 127),
                r.range_i64(-128, 127),
                r.range_i64(-128, 127),
            ]
        },
        |ws| {
            let rep = fine_tune_tuple(&layout, ws);
            if !is_feasible_exact(&layout, &rep.tuned) {
                return Err("tuned tuple infeasible".into());
            }
            if rep.was_feasible && rep.tuned != *ws {
                return Err("feasible tuple was altered".into());
            }
            if rep.distance > 0.2 {
                return Err(format!("tuned too far: BC {}", rep.distance).into());
            }
            for (o, t) in ws.iter().zip(&rep.tuned) {
                if o.signum() != t.signum() && *o != 0 {
                    return Err("sign flipped".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bray_curtis_metric_properties() {
    check(
        "bray-curtis-bounds",
        3000,
        106,
        |r| {
            let u: Vec<i64> = (0..3).map(|_| r.range_i64(1, 127)).collect();
            let v: Vec<i64> = (0..3).map(|_| r.range_i64(1, 127)).collect();
            (u, v)
        },
        |(u, v)| {
            let d = bray_curtis(u, v);
            let d2 = bray_curtis(v, u);
            if d < 0.0 || d > 1.0 {
                return Err(format!("out of range: {d}").into());
            }
            if (d - d2).abs() > 1e-12 {
                return Err("not symmetric".into());
            }
            if u == v && d != 0.0 {
                return Err("identity violated".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_approximation_monotone_under_scaling() {
    // scaling a magnitude by 2 scales its approximation by 2
    // (powers of two factor straight out of Eq. 2's 2^s)
    check(
        "approx-scale-2",
        2000,
        107,
        |r| r.range_i64(1, 64),
        |&m| {
            let a1 = sdmm::manip::approximate(m as u64, 128);
            let a2 = sdmm::manip::approximate(2 * m as u64, 256);
            if a2.approx == 2 * a1.approx {
                Ok(())
            } else {
                Err(format!("{} vs {}", a1.approx, a2.approx).into())
            }
        },
    );
}

#[test]
fn prop_zero_and_sign_symmetry() {
    check(
        "sign-symmetry",
        2000,
        108,
        |r| r.range_i64(1, 127),
        |&v| {
            let (n1, a1) = approximate_signed(v, 8).unwrap();
            let (n2, a2) = approximate_signed(-v, 8).unwrap();
            if n1 || !n2 {
                return Err("sign flags wrong".into());
            }
            if a1.approx != a2.approx {
                return Err("approximation not sign-symmetric".into());
            }
            Ok(())
        },
    );
}
