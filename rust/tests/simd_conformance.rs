//! Scalar ≡ SIMD differential conformance suite (the lockdown for the
//! runtime-dispatched `dsp::simd` tier).
//!
//! Four legs:
//!
//! 1. **Seeded sweeps** (N = 512 random planes each): every stage kind
//!    the inference pipeline vectorizes — SDMM multiply (P words, both
//!    the dense lane-0 stream and the dense multi-lane stream with ki
//!    distinct inputs per group), ReLU, 2×2 maxpool, symmetric
//!    requantization, FC head — diffed bit-for-bit against its scalar
//!    reference on every dispatch rung the host supports, via the
//!    rung-pinned `*_on` kernel variants (no global state, safe under
//!    parallel test threads). The multi-lane sweep checks two
//!    independent oracles: the per-group `p_word` kernel and the
//!    port-accurate `SdmmEngine`.
//! 2. **Sign-correction port edges**: exhaustive input enumeration for
//!    tuples that toggle the DSP48E1 `a24`/`b17` sign bits, against the
//!    port-accurate `SdmmEngine` oracle — once through the dispatched
//!    batch path, once rung-pinned through `p_words_multi_on`.
//! 3. **End-to-end**: `InferenceSession` over random networks ×
//!    {8, 6, 4} bits × every `CompressionPolicy`, against the fully
//!    scalar `ReferenceNet` (which never touches the SIMD tier — the
//!    oracle cannot share a defect with the tier under test).
//! 4. **Golden replay**: the checked-in `net{8,6,4}.txt` vectors replay
//!    bit-exactly with each rung pinned via `Isa::set_override` (the CI
//!    feature matrix additionally pins `SDMM_ISA` per job, covering the
//!    env-var resolution path).

mod common;

use common::{compile_plan, load_fixture};
use sdmm::api::{BatchExec, CompressionPolicy, Executor, InferenceSession, SystolicExec};
use sdmm::cnn::infer::{self as scalar_stage, Tensor3};
use sdmm::cnn::zoo::{ConvLayer, Model, ModelKind};
use sdmm::dsp::simd::{self, resolve};
use sdmm::dsp::{scalar_raw_reference, BatchEngine, BatchLanes, Isa, PreparedTuple, SdmmEngine};
use sdmm::packing::{pack_approx, Layout};
use sdmm::util::rng::Rng;

/// Dense lane-0 pattern streams for a slice of inputs (the documented
/// `BatchLanes::pack_lane0` semantic, rebuilt independently so the test
/// does not trust the packer it is checking).
fn lane0_streams(xs: &[i64], v: u32) -> (Vec<u64>, Vec<u64>) {
    let vmask = (1u64 << v) - 1;
    let p = xs.iter().map(|&x| (x as u64) & vmask).collect();
    let neg = xs
        .iter()
        .map(|&x| if x < 0 { u64::MAX } else { 0 })
        .collect();
    (p, neg)
}

/// Lane-0 inputs padded to full ki-lane groups (idle lanes zero) — the
/// shape the port-accurate oracle consumes.
fn pad_lane0(xs: &[i64], ki: usize) -> Vec<i64> {
    xs.iter()
        .flat_map(|&x| {
            let mut g = vec![0i64; ki];
            g[0] = x;
            g
        })
        .collect()
}

#[test]
fn seeded_sweep_512_planes_scalar_vs_simd_all_stage_kinds() {
    let rungs = Isa::supported();
    let mut rng = Rng::new(0x51D_C0DE);
    for round in 0..512u64 {
        let bits = [8u32, 6, 4][(round % 3) as usize];
        let lim = 1i64 << (bits - 1);
        let layout = Layout::for_bits(bits).unwrap();

        // --- conv stage (the SDMM multiply): random tuple, random plane.
        let ws: Vec<i64> = (0..layout.kw())
            .map(|_| rng.range_i64(-lim, lim - 1))
            .collect();
        let tuple = pack_approx(&layout, &ws).unwrap();
        let pt = PreparedTuple::prepare(&tuple);
        let groups = 1 + rng.below(96) as usize;
        let xs: Vec<i64> = (0..groups).map(|_| rng.range_i64(-lim, lim - 1)).collect();
        let mut engine = SdmmEngine::new();
        let want = scalar_raw_reference(&mut engine, &tuple, &pad_lane0(&xs, layout.ki()));
        let (p, neg) = lane0_streams(&xs, bits);
        for &isa in &rungs {
            let mut got = vec![0u64; groups];
            simd::p_words_lane0_on(isa, &pt, &p, &neg, &mut got);
            assert_eq!(
                got,
                want,
                "round {round}: p_words rung {} diverged (bits {bits}, ws {ws:?})",
                isa.name()
            );
        }
        // The dispatched batch path (whatever rung is active) agrees,
        // for both the dense lane-0 packing and full multi-lane groups.
        let lanes = BatchLanes::pack_lane0(&layout, &xs);
        let mut got = vec![0u64; groups];
        BatchEngine::new().execute_raw_batch(&pt, &lanes, &mut got);
        assert_eq!(got, want, "round {round}: dispatched lane-0 path diverged");
        let full: Vec<i64> = (0..groups * layout.ki())
            .map(|_| rng.range_i64(-lim, lim - 1))
            .collect();
        let want_full = scalar_raw_reference(&mut engine, &tuple, &full);
        let lanes_full = BatchLanes::pack(&layout, &full).unwrap();
        let mut got_full = vec![0u64; groups];
        BatchEngine::new().execute_raw_batch(&pt, &lanes_full, &mut got_full);
        assert_eq!(got_full, want_full, "round {round}: multi-lane path diverged");

        // --- activation plane for the glue stages. Amplitudes cycle
        // through small, conv-accumulator-sized, and huge (the last
        // exercises requantize's exact ≥2^51 scalar fallback).
        let (c, h, w) = (
            1 + rng.below(3) as usize,
            2 + rng.below(8) as usize,
            2 + rng.below(8) as usize,
        );
        let amp = [255i64, 1 << 20, 1 << 46, 1 << 55][(round % 4) as usize];
        let mut t = Tensor3::zeros(c, h, w);
        t.data = (0..t.data.len()).map(|_| rng.range_i64(-amp, amp)).collect();

        // ReLU.
        let mut want_relu = t.clone();
        scalar_stage::relu(&mut want_relu);
        for &isa in &rungs {
            let mut got_relu = t.data.clone();
            simd::relu_on(isa, &mut got_relu);
            assert_eq!(
                got_relu,
                want_relu.data,
                "round {round}: relu rung {} diverged",
                isa.name()
            );
        }

        // 2×2 maxpool (floor semantics on odd dims).
        let want_pool = scalar_stage::maxpool2(&t);
        for &isa in &rungs {
            assert_eq!(
                simd::maxpool2_on(isa, &t),
                want_pool,
                "round {round}: maxpool2 rung {} diverged",
                isa.name()
            );
        }

        // Symmetric requantization back to `bits` activations. The
        // scale is compared by bit pattern: the tiers must agree on the
        // exact f64, not approximately.
        let (want_q, want_qp) = scalar_stage::requantize(&t, bits);
        for &isa in &rungs {
            let (got_q, got_qp) = simd::requantize_on(isa, &t, bits);
            assert_eq!(
                got_q,
                want_q,
                "round {round}: requantize rung {} diverged (amp {amp})",
                isa.name()
            );
            assert_eq!(got_qp.bits, want_qp.bits);
            assert_eq!(
                got_qp.scale.to_bits(),
                want_qp.scale.to_bits(),
                "round {round}: requantize rung {} scale drifted",
                isa.name()
            );
        }

        // FC head.
        let in_f = 1 + rng.below(48) as usize;
        let out_f = 1 + rng.below(12) as usize;
        let fc_in: Vec<i64> = (0..in_f).map(|_| rng.range_i64(-lim, lim - 1)).collect();
        let fc_w: Vec<i64> = (0..in_f * out_f)
            .map(|_| rng.range_i64(-lim, lim - 1))
            .collect();
        let want_fc = scalar_stage::fc_int(&fc_in, &fc_w, in_f, out_f);
        for &isa in &rungs {
            assert_eq!(
                simd::fc_int_on(isa, &fc_in, &fc_w, in_f, out_f),
                want_fc,
                "round {round}: fc rung {} diverged",
                isa.name()
            );
        }
    }
}

/// Independently-built lane-major streams for a dense multi-lane
/// packing (the documented `BatchLanes::pack_multi` layout, rebuilt so
/// the test does not trust the packer it is checking): lane i of group
/// g at `p[i * groups + g]`, tail group zero-padded.
fn multi_streams(xs: &[i64], ki: usize, v: u32) -> (Vec<u64>, Vec<u64>, usize) {
    let groups = xs.len().div_ceil(ki);
    let vmask = (1u64 << v) - 1;
    let mut p = vec![0u64; ki * groups];
    let mut neg = vec![0u64; ki * groups];
    for (f, &x) in xs.iter().enumerate() {
        let idx = (f % ki) * groups + f / ki;
        p[idx] = (x as u64) & vmask;
        neg[idx] = if x < 0 { u64::MAX } else { 0 };
    }
    (p, neg, groups)
}

#[test]
fn seeded_sweep_512_multi_lane_every_rung_vs_p_word_and_engine() {
    // The dense multi-lane leg of leg 1: N = 512 random planes with ki
    // *distinct* inputs per group (the 6/4-bit conv mapping), every
    // rung's `p_words_multi_on` diffed against BOTH scalar oracles —
    // the per-group `PreparedTuple::p_word` and the port-accurate
    // `SdmmEngine` — plus the dispatched `execute_raw_batch` path over
    // `BatchLanes::pack_multi` (zero-padded tails included).
    let rungs = Isa::supported();
    let mut rng = Rng::new(0x3A9E_51D);
    for round in 0..512u64 {
        let bits = [8u32, 6, 4][(round % 3) as usize];
        let lim = 1i64 << (bits - 1);
        let layout = Layout::for_bits(bits).unwrap();
        let ki = layout.ki();
        let ws: Vec<i64> = (0..layout.kw())
            .map(|_| rng.range_i64(-lim, lim - 1))
            .collect();
        let tuple = pack_approx(&layout, &ws).unwrap();
        let pt = PreparedTuple::prepare(&tuple);
        // Lengths off the ki boundary exercise the padded tail group
        // and, with odd group counts, the vector kernels' scalar tails.
        let n = 1 + rng.below(96) as usize;
        let xs: Vec<i64> = (0..n).map(|_| rng.range_i64(-lim, lim - 1)).collect();
        let (p, neg, groups) = multi_streams(&xs, ki, bits);

        // Oracle 1: the port-accurate engine over zero-padded groups.
        let mut padded = xs.clone();
        padded.resize(groups * ki, 0);
        let mut engine = SdmmEngine::new();
        let want = scalar_raw_reference(&mut engine, &tuple, &padded);
        // Oracle 2: the per-group p_word kernel must agree with it.
        for (g, group) in padded.chunks(ki).enumerate() {
            let (gp, gneg, _) = multi_streams(group, ki, bits);
            assert_eq!(
                pt.p_word(&gp, &gneg),
                want[g],
                "round {round}: p_word oracle disagrees with engine (bits {bits})"
            );
        }
        for &isa in &rungs {
            let mut got = vec![0u64; groups];
            simd::p_words_multi_on(isa, &pt, &p, &neg, &mut got);
            assert_eq!(
                got,
                want,
                "round {round}: p_words_multi rung {} diverged (bits {bits}, ws {ws:?})",
                isa.name()
            );
        }
        // The dispatched batch path over the real packer agrees too.
        let lanes = BatchLanes::pack_multi(&layout, &xs);
        assert_eq!(lanes.groups(), groups);
        assert_eq!(lanes.real(), n);
        let mut got = vec![0u64; groups];
        BatchEngine::new().execute_raw_batch(&pt, &lanes, &mut got);
        assert_eq!(got, want, "round {round}: dispatched pack_multi path diverged");
    }
}

#[test]
fn multi_lane_sign_correction_edges_every_rung_exhaustive() {
    // Rung-pinned twin of the dispatched edge sweep below: for tuples
    // that toggle the DSP48E1 `a24` sign bit and layouts whose lanes
    // can toggle `b17` (4-bit lane 2: zext(-x, 4) << 14 reaches bit
    // 17), every ki-lane input combination is enumerated odometer-style
    // and `p_words_multi_on` is diffed per rung against the
    // port-accurate engine — per-lane sign edges included by
    // construction, since every lane sweeps its full signed range.
    let cases: [(u32, &[i64]); 4] = [
        (8, &[1, 1, 15]),
        (8, &[-100, 44, 15]),
        (6, &[5, -3]),
        (4, &[5, -3]),
    ];
    let rungs = Isa::supported();
    let (mut saw_a24, mut saw_b17) = (false, false);
    for (bits, ws) in cases {
        let layout = Layout::for_bits(bits).unwrap();
        let tuple = pack_approx(&layout, ws).unwrap();
        let pt = PreparedTuple::prepare(&tuple);
        saw_a24 |= (tuple.a_word >> 24) & 1 == 1;
        let lim = 1i64 << (bits - 1);
        let ki = layout.ki();
        let per_lane = (2 * lim) as usize;
        let total = per_lane.pow(ki as u32);
        let mut full = Vec::with_capacity(total * ki);
        for idx in 0..total {
            let mut rem = idx;
            let mut group = vec![0i64; ki];
            for lane in group.iter_mut() {
                *lane = (rem % per_lane) as i64 - lim;
                rem /= per_lane;
            }
            saw_b17 |= (tuple.layout.b_word(&group).unwrap() >> 17) & 1 == 1;
            full.extend_from_slice(&group);
        }
        let mut engine = SdmmEngine::new();
        let want = scalar_raw_reference(&mut engine, &tuple, &full);
        let (p, neg, groups) = multi_streams(&full, ki, bits);
        assert_eq!(groups, total);
        for &isa in &rungs {
            let mut got = vec![0u64; total];
            simd::p_words_multi_on(isa, &pt, &p, &neg, &mut got);
            assert_eq!(
                got,
                want,
                "multi-lane edge diverged ({bits} bit, ws {ws:?}, rung {})",
                isa.name()
            );
        }
    }
    assert!(saw_a24, "edge set never toggled a24 — cases need rework");
    assert!(saw_b17, "edge set never toggled b17 — cases need rework");
}

#[test]
fn sign_correction_port_edges_a24_b17_exhaustive() {
    // Tuples chosen so the DSP48E1 sign bits toggle: a negative or
    // wide top slot drives A-word bit 24, and at 4 bit (ki = 3,
    // b_offsets [0,7,14]) a negative lane-2 input drives B-word bit 17
    // (zext(-8, 4) << 14 = 2^17). Every ki-lane input combination is
    // enumerated and diffed against the port-accurate engine.
    let cases: [(u32, &[i64]); 4] = [
        (8, &[1, 1, 15]),
        (8, &[-100, 44, 15]),
        (6, &[5, -3]),
        (4, &[5, -3]),
    ];
    let rungs = Isa::supported();
    let (mut saw_a24, mut saw_b17) = (false, false);
    for (bits, ws) in cases {
        let layout = Layout::for_bits(bits).unwrap();
        assert_eq!(ws.len(), layout.kw(), "case/kw mismatch at {bits} bit");
        let tuple = pack_approx(&layout, ws).unwrap();
        let pt = PreparedTuple::prepare(&tuple);
        saw_a24 |= (tuple.a_word >> 24) & 1 == 1;
        let lim = 1i64 << (bits - 1);
        let ki = layout.ki();

        // Every ki-lane group: lane values enumerated odometer-style.
        let per_lane = (2 * lim) as usize;
        let total = per_lane.pow(ki as u32);
        let mut full = Vec::with_capacity(total * ki);
        for idx in 0..total {
            let mut rem = idx;
            let mut group = vec![0i64; ki];
            for lane in group.iter_mut() {
                *lane = (rem % per_lane) as i64 - lim;
                rem /= per_lane;
            }
            saw_b17 |= (tuple.layout.b_word(&group).unwrap() >> 17) & 1 == 1;
            full.extend_from_slice(&group);
        }
        let mut engine = SdmmEngine::new();
        let want = scalar_raw_reference(&mut engine, &tuple, &full);
        let lanes = BatchLanes::pack(&layout, &full).unwrap();
        let mut got = vec![0u64; total];
        BatchEngine::new().execute_raw_batch(&pt, &lanes, &mut got);
        assert_eq!(got, want, "multi-lane edge case diverged ({bits} bit, ws {ws:?})");

        // Lane-0 dense path (the SIMD kernel) on every rung, all values.
        let xs: Vec<i64> = (-lim..lim).collect();
        let want0 = scalar_raw_reference(&mut engine, &tuple, &pad_lane0(&xs, ki));
        let (p, neg) = lane0_streams(&xs, bits);
        for &isa in &rungs {
            let mut got0 = vec![0u64; xs.len()];
            simd::p_words_lane0_on(isa, &pt, &p, &neg, &mut got0);
            assert_eq!(
                got0,
                want0,
                "lane-0 edge case diverged ({bits} bit, ws {ws:?}, rung {})",
                isa.name()
            );
        }
    }
    assert!(saw_a24, "edge set never toggled a24 — cases need rework");
    assert!(saw_b17, "edge set never toggled b17 — cases need rework");
}

#[test]
fn session_matches_scalar_reference_for_all_policies_and_bits() {
    let policies = [
        CompressionPolicy::None,
        CompressionPolicy::Wrc,
        CompressionPolicy::WrcHuffman,
        CompressionPolicy::PruneWrcHuffman,
    ];
    let mut rng = Rng::new(0xE2E);
    for bits in [8u32, 6, 4] {
        let lim = 1i64 << (bits - 1);
        for policy in policies {
            let model = Model {
                kind: ModelKind::TinyCnn,
                convs: vec![
                    ConvLayer::new("g0", 8, 2, 4, 3, 1, 1, 1),
                    ConvLayer::new("g1", 4, 4, 6, 3, 1, 1, 1),
                ],
                fcs: vec![(24, 5)],
            };
            let cw: Vec<Vec<i64>> = model
                .convs
                .iter()
                .map(|l| (0..l.params()).map(|_| rng.range_i64(-lim, lim - 1)).collect())
                .collect();
            let fw: Vec<Vec<i64>> = model
                .fcs
                .iter()
                .map(|&(i, o)| (0..i * o).map(|_| rng.range_i64(-lim, lim - 1)).collect())
                .collect();
            let l0 = &model.convs[0];
            let mut input = Tensor3::zeros(l0.in_ch, l0.in_hw, l0.in_hw);
            input.data = (0..input.data.len())
                .map(|_| rng.range_i64(-lim, lim - 1))
                .collect();

            let plan = compile_plan(bits, &model, &cw, &fw, "simd-pol", policy);
            // The oracle: ReferenceNet is scalar end-to-end regardless
            // of the active dispatch rung.
            let want = plan.reference().forward(&input).unwrap();
            for &isa in &Isa::supported() {
                let eff = Isa::set_override(Some(isa));
                assert_eq!(eff, isa, "host dropped a rung mid-suite");
                let mut exec = BatchExec::new();
                let out = InferenceSession::new(&plan, &mut exec).infer(&input).unwrap();
                assert_eq!(
                    out.logits,
                    want,
                    "session diverged from reference ({bits} bit, {policy:?}, rung {})",
                    isa.name()
                );
            }
            Isa::set_override(None);
        }
    }
}

#[test]
fn golden_vectors_replay_bit_exact_on_every_rung() {
    for bits in [8u32, 6, 4] {
        let fx = load_fixture(bits);
        let plan = compile_plan(
            bits,
            &fx.model,
            &fx.conv_weights,
            &fx.fc_weights,
            &format!("simd-golden{bits}"),
            CompressionPolicy::None,
        );
        for isa in Isa::supported() {
            Isa::set_override(Some(isa));
            let mut batch = BatchExec::new();
            let mut systolic = SystolicExec::new();
            let execs: [&mut dyn Executor; 2] = [&mut batch, &mut systolic];
            for e in execs {
                let name = e.name();
                let (out, trace) =
                    InferenceSession::new(&plan, e).infer_trace(&fx.input).unwrap();
                assert_eq!(
                    out.logits,
                    fx.logits,
                    "{name} logits != golden on rung {} (net{bits})",
                    isa.name()
                );
                assert_eq!(out.top1, fx.top1);
                for (i, (got, want)) in trace.iter().zip(&fx.stages).enumerate() {
                    assert_eq!(
                        got,
                        want,
                        "{name} stage {i} != golden on rung {} (net{bits})",
                        isa.name()
                    );
                }
            }
        }
        Isa::set_override(None);
    }
}

#[test]
fn sdmm_isa_resolution_vocabulary_and_clamping() {
    // Unset → detected rung, silently.
    assert_eq!(resolve(None, Isa::Avx2), (Isa::Avx2, None));
    // The documented vocabulary, case/whitespace-insensitive.
    for (s, want) in [
        ("scalar", Isa::Scalar),
        (" SSE41 ", Isa::Sse41),
        ("sse4.1", Isa::Sse41),
        ("avx2", Isa::Avx2),
    ] {
        let (got, warn) = resolve(Some(s), Isa::Avx2);
        assert_eq!(got, want, "SDMM_ISA={s:?}");
        assert!(warn.is_none(), "SDMM_ISA={s:?} warned: {warn:?}");
    }
    // Forcing DOWN is always honored (the conformance story)...
    assert_eq!(resolve(Some("scalar"), Isa::Avx2).0, Isa::Scalar);
    // ...forcing UP clamps to the host with a warning...
    let (got, warn) = resolve(Some("avx2"), Isa::Sse41);
    assert_eq!(got, Isa::Sse41);
    assert!(warn.unwrap().contains("clamped"));
    // ...and garbage falls back to detection with a warning.
    let (got, warn) = resolve(Some("pentium"), Isa::Sse41);
    assert_eq!(got, Isa::Sse41);
    assert!(warn.is_some());

    // set_override clamps the same way and reports the effective rung.
    let eff = Isa::set_override(Some(Isa::Avx2));
    assert!(eff <= Isa::detect());
    assert_eq!(eff, Isa::detect().min(Isa::Avx2));
    Isa::set_override(None);

    // The ladder always starts at the scalar reference rung.
    let rungs = Isa::supported();
    assert_eq!(rungs[0], Isa::Scalar);
    assert!(rungs.windows(2).all(|w| w[0] < w[1]));
}
