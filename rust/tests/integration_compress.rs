//! Integration: the full Table 3 compression pipelines on
//! distribution-matched model weights, plus codec round-trips at scale.

use sdmm::compress::{huffman_decode, huffman_encode, prune_magnitude, wrc_compress};
use sdmm::compress::prune::rle_decode_sparse;
use sdmm::compress::prune::rle_encode_sparse;
use sdmm::cnn::weights::synth_model_quantized;
use sdmm::cnn::zoo::{Model, ModelKind};
use sdmm::packing::Layout;

fn alexnet_stream(bits: u32) -> Vec<i64> {
    let model = Model::build(ModelKind::Alexnet);
    synth_model_quantized(&model, bits, 21)
        .into_iter()
        .flat_map(|layer| {
            let stride = (layer.len() / 40_000).max(1);
            layer.into_iter().step_by(stride).collect::<Vec<_>>()
        })
        .collect()
}

#[test]
fn wrc_guarantee_is_data_independent() {
    // WRC % must be exactly the paper's guarantee on ANY stream.
    for (bits, pct) in [(8u32, 66.67), (6, 75.0), (4, 83.33)] {
        let ws = alexnet_stream(bits);
        let layout = Layout::for_bits(bits).unwrap();
        let r = wrc_compress(&layout, &ws, 0.65).unwrap();
        assert!(
            (r.wrc.percent() - pct).abs() < 0.2,
            "bits={bits}: {}",
            r.wrc.percent()
        );
    }
}

#[test]
fn table3_orderings_hold_on_model_weights() {
    // Paper Table 3 shape: P+WRC+H < WRC+H < WRC, and H < WRC.
    let ws = alexnet_stream(8);
    let layout = Layout::for_bits(8).unwrap();
    let r = wrc_compress(&layout, &ws, 0.65).unwrap();
    assert!(r.prune_wrc_huffman.percent() < r.wrc_huffman.percent(), "{r:?}");
    assert!(r.wrc_huffman.percent() < r.wrc.percent(), "{r:?}");
    assert!(r.huffman_only.percent() < r.wrc.percent(), "{r:?}");
    // WROM stays within the paper's 13-bit address space
    assert!(r.wrom_entries as u64 <= 8192, "{}", r.wrom_entries);
}

#[test]
fn huffman_round_trip_at_model_scale() {
    let ws = alexnet_stream(8);
    let (bytes, bits, book) = huffman_encode(&ws);
    assert!(bits > 0);
    assert_eq!(huffman_decode(&bytes, ws.len(), &book).unwrap(), ws);
}

#[test]
fn prune_rle_round_trip_at_model_scale() {
    let ws = alexnet_stream(6);
    let pruned = prune_magnitude(&ws, 0.8).pruned;
    let (sym, _) = rle_encode_sparse(&pruned, 4, 6);
    assert_eq!(rle_decode_sparse(&sym, 4, pruned.len()).unwrap(), pruned);
}

#[test]
fn deeper_pruning_compresses_more() {
    let ws = alexnet_stream(8);
    let layout = Layout::for_bits(8).unwrap();
    let r50 = wrc_compress(&layout, &ws, 0.50).unwrap();
    let r90 = wrc_compress(&layout, &ws, 0.90).unwrap();
    assert!(r90.prune_wrc_huffman.percent() < r50.prune_wrc_huffman.percent());
}

#[test]
fn four_bit_stream_compresses_hardest_relative() {
    // paper Table 3: absolute % grows as bit width shrinks for WRC
    // (less redundancy to remove per weight) — orderings preserved.
    let l8 = wrc_compress(&Layout::for_bits(8).unwrap(), &alexnet_stream(8), 0.65).unwrap();
    let l4 = wrc_compress(&Layout::for_bits(4).unwrap(), &alexnet_stream(4), 0.65).unwrap();
    assert!(l4.wrc.percent() > l8.wrc.percent());
}
