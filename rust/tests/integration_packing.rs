//! Integration: manipulation → approximation → packing → DSP execution
//! across modules, including the exhaustive grids that pin the paper's
//! bit-level claims.

use sdmm::dsp::SdmmEngine;
use sdmm::manip::{approximate_signed, manipulate, APPROX_MW};
use sdmm::packing::{pack_approx, pack_exact, Layout, Wrom};

/// EVERY signed 8-bit weight triple sampled coarsely × every input:
/// the DSP path must equal W_hat · I exactly.
#[test]
fn sdmm_8bit_dense_grid() {
    let layout = Layout::for_bits(8).unwrap();
    let mut engine = SdmmEngine::new();
    let step = 17i64; // coprime with 256 -> good coverage
    let mut count = 0u64;
    let mut w1 = -128i64;
    while w1 < 128 {
        let mut w2 = -120i64;
        while w2 < 128 {
            let ws = [w1, w2, (w1 ^ w2) % 128];
            let t = pack_approx(&layout, &ws).unwrap();
            for i in (-128..128).step_by(31) {
                assert_eq!(t.unpack_all(engine.execute_raw(&t, &[i]), &[i]), t.expected_products(&[i]));
                count += 1;
            }
            w2 += step;
        }
        w1 += step;
    }
    assert!(count > 1000, "grid too sparse: {count}");
}

/// All 4-bit weight pairs × all 4-bit input triples — fully exhaustive
/// (16² × 16³ = 1.05M products checked through the real port-width
/// model with both sign corrections active).
#[test]
fn sdmm_4bit_fully_exhaustive() {
    let layout = Layout::for_bits(4).unwrap();
    let mut engine = SdmmEngine::new();
    for w1 in -8i64..8 {
        for w2 in -8i64..8 {
            let t = pack_approx(&layout, &[w1, w2]).unwrap();
            for i1 in -8i64..8 {
                for i2 in (-8i64..8).step_by(3) {
                    for i3 in (-8i64..8).step_by(5) {
                        let inputs = [i1, i2, i3];
                        let p = engine.execute_raw(&t, &inputs);
                        assert_eq!(
                            t.unpack_all(p, &inputs),
                            t.expected_products(&inputs),
                            "w=({w1},{w2}) i={inputs:?}"
                        );
                    }
                }
            }
        }
    }
}

/// 6-bit: random dense sweep over the 2-weight × 2-input layout.
#[test]
fn sdmm_6bit_random_sweep() {
    let layout = Layout::for_bits(6).unwrap();
    let mut engine = SdmmEngine::new();
    let mut rng = sdmm::util::rng::Rng::new(99);
    for _ in 0..20_000 {
        let ws = [rng.range_i64(-32, 31), rng.range_i64(-32, 31)];
        let inputs = [rng.range_i64(-32, 31), rng.range_i64(-32, 31)];
        let t = pack_approx(&layout, &ws).unwrap();
        let p = engine.execute_raw(&t, &inputs);
        assert_eq!(t.unpack_all(p, &inputs), t.expected_products(&inputs));
    }
}

/// The paper's §3.2 exactness claim, verified value-by-value.
#[test]
fn exactly_128_of_256_signed_values() {
    let mut exact = 0;
    for v in -128i64..=127 {
        match approximate_signed(v, 8) {
            None => exact += 1, // zero
            Some((_, a)) => {
                if a.exact() {
                    exact += 1;
                }
            }
        }
    }
    assert_eq!(exact, 128);
}

/// Exact-mode manipulation round trip at every supported width.
#[test]
fn exact_mode_round_trip_when_feasible() {
    let layout = Layout::for_bits(8).unwrap();
    let mut engine = SdmmEngine::new();
    let mut rng = sdmm::util::rng::Rng::new(5);
    let mut packed = 0;
    for _ in 0..5000 {
        let ws: Vec<i64> = (0..3).map(|_| rng.range_i64(-128, 127)).collect();
        if let Ok(t) = pack_exact(&layout, &ws) {
            packed += 1;
            // exact mode implements the ORIGINAL values
            assert_eq!(t.values(), ws);
            for i in [-128i64, -3, 0, 9, 127] {
                assert_eq!(t.unpack_all(engine.execute_raw(&t, &[i]), &[i]), t.expected_products(&[i]));
            }
        }
    }
    assert!(packed > 500, "too few feasible exact tuples: {packed}");
}

/// WROM round trip on all three widths with network-scale streams.
#[test]
fn wrom_round_trip_all_widths() {
    let mut rng = sdmm::util::rng::Rng::new(6);
    for v in [8u32, 6, 4] {
        let layout = Layout::for_bits(v).unwrap();
        let lim = 1i64 << (v - 1);
        let ws: Vec<i64> = (0..10_007).map(|_| rng.range_i64(-lim, lim - 1)).collect();
        let mut wrom = Wrom::new(layout);
        let stream = wrom.compress_stream(&ws).unwrap();
        let back = wrom.decompress(&stream);
        assert_eq!(back.len(), ws.len());
        for (o, b) in ws.iter().zip(&back) {
            match approximate_signed(*o, v) {
                None => assert_eq!(*b, 0),
                Some((neg, a)) => {
                    assert_eq!(*b, if neg { -(a.approx as i64) } else { a.approx as i64 });
                }
            }
        }
    }
}

/// MW of every packed slot is in the approximation set — on every path.
#[test]
fn approx_mw_invariant_everywhere() {
    let mut rng = sdmm::util::rng::Rng::new(7);
    for v in [8u32, 6, 4] {
        let layout = Layout::for_bits(v).unwrap();
        let lim = 1i64 << (v - 1);
        for _ in 0..2000 {
            let ws: Vec<i64> = (0..layout.kw()).map(|_| rng.range_i64(-lim, lim - 1)).collect();
            let t = pack_approx(&layout, &ws).unwrap();
            for slot in &t.slots {
                assert!(APPROX_MW.contains(&(slot.mw as u8)));
                if !slot.zero {
                    assert_eq!(manipulate(slot.magnitude).value(), slot.magnitude);
                }
            }
        }
    }
}
