//! Shared golden-fixture helpers for the conformance suites
//! (`golden_network.rs`, `simd_conformance.rs`).
//!
//! The checked-in vectors under `rust/src/resources/golden/` are minted
//! exclusively by the exact scalar `ReferenceNet` (see `regen_golden`);
//! both suites replay them through independent code paths, so the
//! parser and the deterministic case recipe live here once.

// Each integration-test crate compiles this module independently and
// uses a different subset of it.
#![allow(dead_code)]

use sdmm::api::{ApproxPolicy, Compiler, CompressionPolicy, NetworkPlan};
use sdmm::cnn::infer::Tensor3;
use sdmm::dsp::PackGeneration;
use sdmm::cnn::zoo::{ConvLayer, Model, ModelKind};
use sdmm::util::rng::Rng;
use std::path::PathBuf;

/// Directory of the checked-in vectors (inside the crate source tree,
/// so the suites need no artifacts and run everywhere).
pub fn golden_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src/resources/golden"))
}

/// Static layer names for fixtures (ConvLayer::name is &'static str).
pub static STAGE_NAMES: [&str; 4] = ["g0", "g1", "g2", "g3"];

pub struct Fixture {
    pub bits: u32,
    pub seed: u64,
    pub model: Model,
    pub pools: Vec<bool>,
    pub conv_weights: Vec<Vec<i64>>,
    pub fc_weights: Vec<Vec<i64>>,
    pub input: Tensor3,
    pub stages: Vec<Tensor3>,
    pub logits: Vec<i64>,
    pub top1: usize,
}

/// Sequential token cursor over the fixture text (comment lines
/// stripped). Panics with the offending keyword on malformed input —
/// a corrupted checked-in vector should fail loudly.
pub struct Cursor<'a> {
    toks: Vec<&'a str>,
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(text: &'a str) -> Cursor<'a> {
        Cursor {
            toks: text
                .lines()
                .filter(|l| !l.trim_start().starts_with('#'))
                .flat_map(|l| l.split_whitespace())
                .collect(),
            pos: 0,
        }
    }

    pub fn tok(&mut self) -> &'a str {
        let t = self.toks.get(self.pos).copied().expect("golden vector truncated");
        self.pos += 1;
        t
    }

    pub fn expect(&mut self, kw: &str) {
        let t = self.tok();
        assert_eq!(t, kw, "golden vector: expected keyword {kw:?}, found {t:?}");
    }

    pub fn usize(&mut self) -> usize {
        self.tok().parse().expect("golden vector: bad integer")
    }

    pub fn i64(&mut self) -> i64 {
        self.tok().parse().expect("golden vector: bad integer")
    }

    pub fn ints(&mut self, n: usize) -> Vec<i64> {
        (0..n).map(|_| self.i64()).collect()
    }
}

pub fn parse_fixture(text: &str) -> Fixture {
    let mut c = Cursor::new(text);
    c.expect("bits");
    let bits = c.usize() as u32;
    c.expect("seed");
    let seed = c.usize() as u64;
    c.expect("layers");
    let n_layers = c.usize();
    let mut convs = Vec::with_capacity(n_layers);
    let mut pools = Vec::with_capacity(n_layers);
    for i in 0..n_layers {
        c.expect("layer");
        let (in_hw, in_ch, out_ch) = (c.usize(), c.usize(), c.usize());
        let (kernel, stride, pad, groups) = (c.usize(), c.usize(), c.usize(), c.usize());
        pools.push(c.usize() == 1);
        convs.push(ConvLayer::new(
            STAGE_NAMES[i], in_hw, in_ch, out_ch, kernel, stride, pad, groups,
        ));
    }
    c.expect("fc");
    let fc = (c.usize(), c.usize());
    let model = Model {
        kind: ModelKind::TinyCnn,
        convs,
        fcs: vec![fc],
    };
    let mut conv_weights = Vec::with_capacity(n_layers);
    for i in 0..n_layers {
        c.expect("weights");
        assert_eq!(c.usize(), i, "golden vector: weights out of order");
        let n = c.usize();
        conv_weights.push(c.ints(n));
    }
    c.expect("fcweights");
    let n = c.usize();
    let fc_weights = vec![c.ints(n)];
    c.expect("input");
    let (ic, ih, iw) = (c.usize(), c.usize(), c.usize());
    let input = Tensor3 {
        c: ic,
        h: ih,
        w: iw,
        data: c.ints(ic * ih * iw),
    };
    let mut stages = Vec::with_capacity(n_layers);
    for i in 0..n_layers {
        c.expect("stage");
        assert_eq!(c.usize(), i, "golden vector: stages out of order");
        let (sc, sh, sw) = (c.usize(), c.usize(), c.usize());
        stages.push(Tensor3 {
            c: sc,
            h: sh,
            w: sw,
            data: c.ints(sc * sh * sw),
        });
    }
    c.expect("logits");
    let n = c.usize();
    let logits = c.ints(n);
    c.expect("top1");
    let top1 = c.usize();
    assert_eq!(c.pos, c.toks.len(), "golden vector: trailing tokens");
    Fixture {
        bits,
        seed,
        model,
        pools,
        conv_weights,
        fc_weights,
        input,
        stages,
        logits,
        top1,
    }
}

/// Load and parse the checked-in vector for one bit width.
pub fn load_fixture(bits: u32) -> Fixture {
    let path = golden_dir().join(format!("net{bits}.txt"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading golden vector {path:?}: {e}"));
    let fx = parse_fixture(&text);
    assert_eq!(fx.bits, bits, "vector/file bit-width mismatch");
    fx
}

/// The deterministic golden case: geometry + seeded weights/input.
/// Must stay in lockstep with the checked-in vectors (regen_golden
/// rewrites them from exactly this recipe).
pub fn golden_case(bits: u32) -> (Model, Vec<Vec<i64>>, Vec<Vec<i64>>, Tensor3, u64) {
    let model = Model {
        kind: ModelKind::TinyCnn,
        convs: vec![
            ConvLayer::new("g0", 8, 2, 4, 3, 1, 1, 1),
            ConvLayer::new("g1", 4, 4, 6, 3, 1, 1, 1),
        ],
        fcs: vec![(24, 5)],
    };
    let seed = 9000 + bits as u64;
    let lim = 1i64 << (bits - 1);
    let mut rng = Rng::new(seed);
    let conv_w: Vec<Vec<i64>> = model
        .convs
        .iter()
        .map(|l| (0..l.params()).map(|_| rng.range_i64(-lim, lim - 1)).collect())
        .collect();
    let fc_w: Vec<Vec<i64>> = model
        .fcs
        .iter()
        .map(|&(i, o)| (0..i * o).map(|_| rng.range_i64(-lim, lim - 1)).collect())
        .collect();
    let l0 = &model.convs[0];
    let mut input = Tensor3::zeros(l0.in_ch, l0.in_hw, l0.in_hw);
    input.data = (0..input.data.len()).map(|_| rng.range_i64(-lim, lim - 1)).collect();
    (model, conv_w, fc_w, input, seed)
}

pub fn compile_plan(
    fx_bits: u32,
    model: &Model,
    cw: &[Vec<i64>],
    fw: &[Vec<i64>],
    name: &str,
    policy: CompressionPolicy,
) -> NetworkPlan {
    compile_plan_gen(PackGeneration::Dsp48E1, fx_bits, model, cw, fw, name, policy)
}

/// [`compile_plan`] on an explicit packing generation (the
/// cross-generation conformance suite replays the golden vectors on
/// the DSP58 layouts through this).
pub fn compile_plan_gen(
    generation: PackGeneration,
    fx_bits: u32,
    model: &Model,
    cw: &[Vec<i64>],
    fw: &[Vec<i64>],
    name: &str,
    policy: CompressionPolicy,
) -> NetworkPlan {
    NetworkPlan::compile(
        &Compiler::for_generation(generation, fx_bits)
            .unwrap()
            .approximate(ApproxPolicy::nearest())
            .compress(policy),
        name,
        model,
        cw,
        fw,
    )
    .unwrap()
}
