//! Chaos suite: replay deterministic fault plans against the sharded
//! serving runtime and assert the exactly-once / bit-exact / recovery
//! contract (DESIGN.md §10, EXPERIMENTS.md "Chaos protocol").
//!
//! Every test is seeded — the same seed replays the same plan on any
//! machine — and none relies on wall-clock sleeps for correctness:
//! delays only bound liveness waits (bounded polling), never decide
//! pass/fail.
//!
//! CI runs this file once per seed in its matrix with
//! `SDMM_CHAOS_SEED=<seed>`; without the variable the built-in seed set
//! is used.

use sdmm::cnn::infer::{relu, requantize, Tensor3};
use sdmm::cnn::zoo::ConvLayer;
use sdmm::coordinator::{
    AdmitError, ModelRegistry, ModelSpec, ServingConfig, ServingRuntime, ShardState,
    SupervisionPolicy,
};
use sdmm::error::SdmmError;
use sdmm::fault::{FaultEvent, FaultKind, FaultPlan, FaultSpec};
use sdmm::sa::{PeArch, SaConfig, SystolicArray};
use sdmm::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

/// Fixed replay seeds (CI runs one per matrix leg). `SDMM_CHAOS_SEED`
/// overrides the whole set with a single seed for targeted replays.
fn chaos_seeds() -> Vec<u64> {
    match std::env::var("SDMM_CHAOS_SEED") {
        Ok(v) => vec![v.parse().expect("SDMM_CHAOS_SEED must be a u64")],
        Err(_) => vec![7, 42, 0xC0FFEE],
    }
}

/// Mixed-precision model set (one 2-conv model per bit width) plus a
/// seeded in-range input per model — mirrors the integration suite so
/// chaos runs exercise the same packed planes.
fn mixed_set() -> Vec<(ModelSpec, Tensor3)> {
    [8u32, 6, 4]
        .iter()
        .map(|&v| {
            let layers = vec![
                ConvLayer::new("c1", 8, 4, 6, 3, 1, 1, 1),
                ConvLayer::new("c2", 8, 6, 6, 3, 1, 1, 1),
            ];
            let spec = ModelSpec::random("net", v, layers, 300 + v as u64);
            let lim = 1i64 << (v - 1);
            let mut rng = Rng::new(400 + v as u64);
            let mut input = Tensor3::zeros(4, 8, 8);
            input.data = (0..input.data.len())
                .map(|_| rng.range_i64(-lim, lim - 1))
                .collect();
            (spec, input)
        })
        .collect()
}

fn registry_for(set: &[(ModelSpec, Tensor3)]) -> Arc<ModelRegistry> {
    let reg = Arc::new(ModelRegistry::new());
    for (spec, _) in set {
        reg.register(spec.clone()).unwrap();
    }
    reg
}

/// The no-fault reference: the pre-existing single-shard batch path
/// with the runtime's ReLU/requantize interleaving. Both the packed
/// tier and the scalar degradation tier must match it bit-exactly.
fn reference_forward(spec: &ModelSpec, input: &Tensor3) -> Tensor3 {
    let sa =
        SystolicArray::new(SaConfig::paper_prototype(spec.v_bits, PeArch::MultiPack)).unwrap();
    let mut x = input.clone();
    for (layer, w) in spec.layers.iter().zip(&spec.weights) {
        let mut y = sa.run_conv_batch(layer, w, &x).unwrap().output.unwrap();
        relu(&mut y);
        x = requantize(&y, spec.v_bits).0;
    }
    x
}

/// Short backoffs so a replay converges quickly; the generous restart
/// cap keeps light plans from ever killing a shard.
fn chaos_policy(retry_budget: u32) -> SupervisionPolicy {
    SupervisionPolicy {
        max_restarts: 8,
        initial_backoff: Duration::from_micros(200),
        max_backoff: Duration::from_millis(2),
        default_retry_budget: retry_budget,
    }
}

/// Bounded liveness wait: poll the snapshot until every shard is Up
/// with an empty queue. Panics with the final snapshot if the runtime
/// never converges (the bound is generous; the expected wait is one
/// backoff, ≤ 2 ms under `chaos_policy`).
fn await_healthy(rt: &ServingRuntime) {
    for _ in 0..20_000 {
        if rt.snapshot().healthy() {
            return;
        }
        std::thread::sleep(Duration::from_micros(100));
    }
    panic!("runtime never recovered to healthy: {:?}", rt.snapshot());
}

#[test]
fn seeded_plans_replay_with_exactly_once_bit_exact_delivery() {
    let set = mixed_set();
    let references: Vec<Tensor3> =
        set.iter().map(|(s, x)| reference_forward(s, x)).collect();
    for seed in chaos_seeds() {
        let shards = 3usize;
        let n = 60usize;
        let spec = FaultSpec::light(shards, (n / shards) as u64);
        let plan = FaultPlan::generate(seed, &spec);
        assert_eq!(
            plan.events,
            FaultPlan::generate(seed, &spec).events,
            "plan generation must be deterministic"
        );
        // Budget sized so no request can out-crash it: each planned
        // panic fires exactly once, so a single request survives at
        // most `panics()` crashes — every submission must succeed.
        let budget = (plan.panics() as u32).max(2);
        let registry = registry_for(&set);
        let rt = ServingRuntime::start_supervised(
            Arc::clone(&registry),
            ServingConfig {
                shards,
                queue_capacity: 128,
            },
            chaos_policy(budget),
            Some(plan),
        )
        .unwrap();
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                let (spec, input) = &set[i % set.len()];
                rt.submit(&spec.key(), input.clone()).unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let out = rx
                .recv()
                .unwrap_or_else(|_| panic!("seed {seed}: request {i} dropped"))
                .unwrap_or_else(|e| panic!("seed {seed}: request {i} failed: {e}"));
            assert_eq!(
                out.output,
                references[i % set.len()],
                "seed {seed}: request {i} not bit-exact (degraded={})",
                out.degraded
            );
            assert!(rx.recv().is_err(), "seed {seed}: request {i} answered twice");
        }
        // Full recovery: every shard back Up with an empty queue.
        await_healthy(&rt);
        let snap = rt.shutdown();
        assert_eq!(snap.total_jobs(), n as u64, "seed {seed}");
        assert_eq!(snap.total_failed(), 0, "seed {seed}");
        assert_eq!(
            snap.total_panics(),
            snap.total_restarts(),
            "seed {seed}: every caught panic must be followed by a restart"
        );
        assert_eq!(snap.dead_shards(), 0, "seed {seed}");
        assert!(snap.healthy(), "seed {seed}: final snapshot not healthy");
    }
}

#[test]
fn crash_past_budget_kills_the_shard_and_peers_take_over() {
    let set = mixed_set();
    let (spec, input) = &set[0];
    let want = reference_forward(spec, input);
    let registry = registry_for(&set);
    // A zero-restart policy with one planned panic on shard 0's first
    // job: the crash immediately exhausts the budget, the shard dies,
    // and the in-flight job must be retried on the surviving peer.
    let plan = FaultPlan {
        seed: 0,
        events: vec![FaultEvent {
            shard: 0,
            nth: 0,
            kind: FaultKind::WorkerPanic,
        }],
        flips: Vec::new(),
    };
    let rt = ServingRuntime::start_supervised(
        Arc::clone(&registry),
        ServingConfig {
            shards: 2,
            queue_capacity: 16,
        },
        SupervisionPolicy {
            max_restarts: 0,
            ..chaos_policy(2)
        },
        Some(plan),
    )
    .unwrap();
    // Serialized submissions: with idle queues the least-loaded scan
    // admits to shard 0 first, which fires the planned panic.
    let out = rt.infer(&spec.key(), input.clone()).unwrap();
    assert_eq!(out.output, want, "retried job must stay bit-exact");
    assert_eq!(out.shard, 1, "retry must land on the surviving peer");
    // The dead shard is gated out of admission; traffic keeps flowing.
    for _ in 0..4 {
        let out = rt.infer(&spec.key(), input.clone()).unwrap();
        assert_eq!(out.shard, 1);
        assert_eq!(out.output, want);
    }
    let snap = rt.shutdown();
    assert_eq!(snap.dead_shards(), 1);
    assert_eq!(snap.shards[0].state, ShardState::Dead);
    assert_eq!(snap.shards[0].panics, 1);
    assert_eq!(snap.shards[0].restarts, 0);
    assert_eq!(snap.shards[1].jobs_ok, 5);
    assert_eq!(snap.shards[1].retries, 1, "one cross-shard retry transfer");
    assert!(!snap.healthy());
}

#[test]
fn all_shards_dead_fails_typed_and_gates_admission() {
    let set = mixed_set();
    let (spec, input) = &set[0];
    let registry = registry_for(&set);
    let plan = FaultPlan {
        seed: 0,
        events: vec![FaultEvent {
            shard: 0,
            nth: 0,
            kind: FaultKind::WorkerPanic,
        }],
        flips: Vec::new(),
    };
    let rt = ServingRuntime::start_supervised(
        Arc::clone(&registry),
        ServingConfig {
            shards: 1,
            queue_capacity: 8,
        },
        SupervisionPolicy {
            max_restarts: 0,
            ..chaos_policy(2)
        },
        Some(plan),
    )
    .unwrap();
    // The only shard dies on the first job; with no healthy peer the
    // request must fail with a typed error — never hang.
    let err = rt.infer(&spec.key(), input.clone()).unwrap_err();
    assert!(
        matches!(err.root(), SdmmError::ShardUnavailable { shard: 0 }),
        "expected ShardUnavailable, got: {err}"
    );
    // Admission now refuses outright (typed), before queuing anything.
    assert!(matches!(
        rt.submit(&spec.key(), input.clone()),
        Err(AdmitError::NoHealthyShards)
    ));
    let snap = rt.shutdown();
    assert_eq!(snap.dead_shards(), 1);
    assert_eq!(snap.total_jobs(), 0);
    assert_eq!(snap.total_failed(), 1);
}

#[test]
fn forced_degradation_serves_bit_exact_from_the_scalar_tier() {
    let set = mixed_set();
    let registry = registry_for(&set);
    let n = 6u64;
    // Force the scalar tier for every one of the n jobs on the single
    // shard: outputs must stay bit-exact with the packed path.
    let plan = FaultPlan {
        seed: 0,
        events: (0..n)
            .map(|nth| FaultEvent {
                shard: 0,
                nth,
                kind: FaultKind::DegradePackedPath,
            })
            .collect(),
        flips: Vec::new(),
    };
    let rt = ServingRuntime::start_supervised(
        Arc::clone(&registry),
        ServingConfig {
            shards: 1,
            queue_capacity: 16,
        },
        chaos_policy(2),
        Some(plan),
    )
    .unwrap();
    for i in 0..n as usize {
        let (spec, input) = &set[i % set.len()];
        let want = reference_forward(spec, input);
        let out = rt.infer(&spec.key(), input.clone()).unwrap();
        assert!(out.degraded, "job {i} should have been forced scalar");
        assert_eq!(out.output, want, "scalar tier diverged on job {i}");
    }
    assert_eq!(rt.faults_fired(), n);
    let snap = rt.shutdown();
    assert_eq!(snap.total_degraded(), n);
    assert_eq!(snap.total_jobs(), n);
    assert_eq!(snap.total_failed(), 0);
    assert!(snap.healthy(), "degradation must not cost health");
}

#[test]
fn shutdown_under_saturation_with_faults_resolves_every_request_once() {
    let set = mixed_set();
    let references: Vec<Tensor3> =
        set.iter().map(|(s, x)| reference_forward(s, x)).collect();
    let registry = registry_for(&set);
    let shards = 2usize;
    let n = 24usize;
    let spec = FaultSpec::light(shards, (n / shards) as u64);
    let plan = FaultPlan::generate(9_001, &spec);
    let budget = (plan.panics() as u32).max(2);
    let rt = ServingRuntime::start_supervised(
        Arc::clone(&registry),
        ServingConfig {
            shards,
            queue_capacity: 64,
        },
        chaos_policy(budget),
        Some(plan),
    )
    .unwrap();
    // Saturate, then shut down with everything still in flight: every
    // admitted request must resolve exactly once — bit-exact or typed.
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let (spec, input) = &set[i % set.len()];
            rt.submit(&spec.key(), input.clone()).unwrap()
        })
        .collect();
    let snap = rt.shutdown();
    let (mut ok, mut typed) = (0u64, 0u64);
    for (i, rx) in rxs.into_iter().enumerate() {
        match rx.recv().unwrap_or_else(|_| panic!("request {i} dropped")) {
            Ok(out) => {
                assert_eq!(out.output, references[i % set.len()], "request {i}");
                ok += 1;
            }
            Err(e) => {
                assert!(
                    matches!(
                        e.root(),
                        SdmmError::ShardUnavailable { .. } | SdmmError::DeadlineExceeded { .. }
                    ),
                    "request {i}: untyped failure {e}"
                );
                typed += 1;
            }
        }
        assert!(rx.recv().is_err(), "request {i} answered twice");
    }
    assert_eq!(ok + typed, n as u64);
    assert_eq!(snap.total_jobs() + snap.total_failed(), n as u64);
    assert_eq!(snap.total_jobs(), ok);
    assert_eq!(snap.total_failed(), typed);
}

#[test]
fn zero_deadline_fails_typed_while_the_runtime_stays_healthy() {
    use sdmm::coordinator::SubmitOptions;
    let set = mixed_set();
    let (spec, input) = &set[0];
    let registry = registry_for(&set);
    let rt = ServingRuntime::start(
        Arc::clone(&registry),
        ServingConfig {
            shards: 1,
            queue_capacity: 8,
        },
    )
    .unwrap();
    // A zero budget is already expired at admission — deterministic
    // typed failure with no wall-clock dependence at all.
    let rx = rt
        .submit_with(
            &spec.key(),
            input.clone(),
            SubmitOptions {
                deadline: Some(Duration::ZERO),
                retry_budget: None,
            },
        )
        .unwrap();
    let err = rx.recv().unwrap().unwrap_err();
    assert!(
        matches!(err.root(), SdmmError::DeadlineExceeded { .. }),
        "expected DeadlineExceeded, got: {err}"
    );
    // An expired request must not poison the shard for its successors.
    let out = rt.infer(&spec.key(), input.clone()).unwrap();
    assert_eq!(out.output, reference_forward(spec, input));
    let snap = rt.shutdown();
    assert_eq!(snap.total_expired(), 1);
    assert_eq!(snap.total_jobs(), 1);
    assert!(snap.healthy());
}
