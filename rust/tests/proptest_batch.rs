//! Property tests: the lane-parallel batch engine is bit-exact with
//! the port-accurate scalar engine (util::check harness — proptest is
//! not vendored; the harness runs seeded randomized cases and reports
//! the reproducing input on failure).
//!
//! Covered: every shipped layout (v ∈ {4, 6, 8}), the mixed (W, I)
//! width grid of Table 2 (including n ≥ v shift paths), and both port
//! sign-correction edge cases of `dsp/engine.rs` — the A-port bit-24
//! case (v=8 top slot MW ≥ 4) and the B-port bit-17 case (v=4 negative
//! top-lane input).

use sdmm::dsp::{scalar_raw_reference, BatchEngine, BatchLanes, PreparedTuple, SdmmEngine};
use sdmm::error::SdmmError;
use sdmm::packing::{pack_approx, Layout};
use sdmm::util::check::check;

fn raw_equal(
    layout: &Layout,
    ws: &[i64],
    inputs: &[i64],
    scalar: &mut SdmmEngine,
    batch: &mut BatchEngine,
) -> Result<(), SdmmError> {
    let t = pack_approx(layout, ws)?;
    let pt = PreparedTuple::prepare(&t);
    let lanes = BatchLanes::pack(layout, inputs)?;
    let mut raw = vec![0u64; lanes.groups()];
    batch.execute_raw_batch(&pt, &lanes, &mut raw);
    let want = scalar_raw_reference(scalar, &t, inputs);
    if raw == want {
        Ok(())
    } else {
        Err(format!("raw P words diverge: {raw:?} != {want:?}").into())
    }
}

#[test]
fn prop_batch_raw_equals_scalar_all_layouts() {
    for v in [8u32, 6, 4] {
        let layout = Layout::for_bits(v).unwrap();
        let lim = 1i64 << (v - 1);
        let (kw, ki) = (layout.kw(), layout.ki());
        let mut scalar = SdmmEngine::new();
        let mut batch = BatchEngine::new();
        check(
            "batch-raw-equals-scalar",
            3000,
            200 + v as u64,
            |r| {
                let ws: Vec<i64> = (0..kw).map(|_| r.range_i64(-lim, lim - 1)).collect();
                let is: Vec<i64> =
                    (0..ki * 8).map(|_| r.range_i64(-lim, lim - 1)).collect();
                (ws, is)
            },
            |(ws, is)| raw_equal(&layout, ws, is, &mut scalar, &mut batch),
        );
    }
}

#[test]
fn prop_batch_raw_equals_scalar_mixed_widths() {
    // Table 2 sweeps (W, I) over {8, 6, 4}²; c > v drives slot shifts
    // n ≥ v through the hi-mask path of the prepared constants.
    for c in [8u32, 6, 4] {
        for v in [8u32, 6, 4] {
            let layout = Layout::for_bits_wc(c, v).unwrap();
            let wlim = 1i64 << (c - 1);
            let ilim = 1i64 << (v - 1);
            let (kw, ki) = (layout.kw(), layout.ki());
            let mut scalar = SdmmEngine::new();
            let mut batch = BatchEngine::new();
            check(
                "batch-raw-mixed-widths",
                1500,
                300 + (c * 10 + v) as u64,
                |r| {
                    let ws: Vec<i64> =
                        (0..kw).map(|_| r.range_i64(-wlim, wlim - 1)).collect();
                    let is: Vec<i64> =
                        (0..ki * 4).map(|_| r.range_i64(-ilim, ilim - 1)).collect();
                    (ws, is)
                },
                |(ws, is)| raw_equal(&layout, ws, is, &mut scalar, &mut batch),
            );
        }
    }
}

#[test]
fn prop_batch_products_equal_scalar_execute() {
    for v in [8u32, 6, 4] {
        let layout = Layout::for_bits(v).unwrap();
        let lim = 1i64 << (v - 1);
        let (kw, ki) = (layout.kw(), layout.ki());
        let mut scalar = SdmmEngine::new();
        let mut batch = BatchEngine::new();
        let mut scratch: Vec<u64> = Vec::new();
        check(
            "batch-products-equal-execute",
            2000,
            400 + v as u64,
            |r| {
                let ws: Vec<i64> = (0..kw).map(|_| r.range_i64(-lim, lim - 1)).collect();
                let is: Vec<i64> =
                    (0..ki * 4).map(|_| r.range_i64(-lim, lim - 1)).collect();
                (ws, is)
            },
            |(ws, is)| {
                let t = pack_approx(&layout, ws)?;
                let pt = PreparedTuple::prepare(&t);
                let lanes = BatchLanes::pack(&layout, is)?;
                let k = kw * ki;
                let mut got = vec![0i64; lanes.groups() * k];
                batch.execute_batch_into(&pt, &lanes, &mut scratch, &mut got);
                for (g, group) in is.chunks(ki).enumerate() {
                    let want: Vec<i64> =
                        scalar.execute(&t, group).into_iter().flatten().collect();
                    if got[g * k..(g + 1) * k] != want[..] {
                        return Err(format!(
                            "group {g}: {:?} != {want:?}",
                            &got[g * k..(g + 1) * k]
                        )
                        .into());
                    }
                    // and the oracle products
                    let oracle: Vec<i64> =
                        t.expected_products(group).into_iter().flatten().collect();
                    if want != oracle {
                        return Err(format!("scalar engine vs oracle: {want:?} != {oracle:?}").into());
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_a_sign_correction_edge_bit_exact() {
    // Top-slot magnitudes whose packed MW sets A bit 24 (v=8, MW ≥ 4):
    // the engine folds a +B<<25 correction into C; the batch engine's
    // unsigned identity must reproduce it exactly.
    let layout = Layout::for_bits(8).unwrap();
    let mags: Vec<i64> = (1..=128i64)
        .filter(|&m| {
            pack_approx(&layout, &[0, 0, m])
                .map(|t| t.a_sign_correction())
                .unwrap_or(false)
        })
        .collect();
    assert!(!mags.is_empty(), "no bit-24 magnitudes found");
    let mut scalar = SdmmEngine::new();
    let mut batch = BatchEngine::new();
    check(
        "a-sign-correction-edge",
        2000,
        500,
        |r| {
            let top = *r.choose(&mags) * if r.bool(0.5) { -1 } else { 1 };
            let ws = vec![r.range_i64(-128, 127), r.range_i64(-128, 127), top];
            let is: Vec<i64> = (0..4).map(|_| r.range_i64(-128, 127)).collect();
            (ws, is)
        },
        |(ws, is)| {
            let t = pack_approx(&layout, ws)?;
            if !t.a_sign_correction() {
                return Err(format!("edge not exercised for {ws:?}").into());
            }
            raw_equal(&layout, ws, is, &mut scalar, &mut batch)
        },
    );
}

#[test]
fn prop_b_sign_correction_edge_bit_exact() {
    // v=4 layout: a negative input in the top lane (bits 14..17 of B)
    // sets B bit 17; the engine folds +A<<18 into C.
    let layout = Layout::for_bits(4).unwrap();
    let mut scalar = SdmmEngine::new();
    let mut batch = BatchEngine::new();
    check(
        "b-sign-correction-edge",
        2000,
        501,
        |r| {
            let ws: Vec<i64> = (0..2).map(|_| r.range_i64(-8, 7)).collect();
            // top lane strictly negative in every group
            let is: Vec<i64> = (0..4 * 3)
                .map(|i| {
                    if i % 3 == 2 {
                        r.range_i64(-8, -1)
                    } else {
                        r.range_i64(-8, 7)
                    }
                })
                .collect();
            (ws, is)
        },
        |(ws, is)| {
            for group in is.chunks(3) {
                if (layout.b_word(group).unwrap() >> 17) & 1 != 1 {
                    return Err(format!("edge not exercised for {group:?}").into());
                }
            }
            raw_equal(&layout, ws, is, &mut scalar, &mut batch)
        },
    );
}

#[test]
fn prop_lane0_accumulation_equals_weight_times_input() {
    // The conv inner loop: accumulated lane-0 products equal the
    // approximated weights times the inputs, summed per slot.
    for v in [8u32, 6, 4] {
        let layout = Layout::for_bits(v).unwrap();
        let lim = 1i64 << (v - 1);
        let kw = layout.kw();
        let mut batch = BatchEngine::new();
        let mut scratch: Vec<u64> = Vec::new();
        check(
            "lane0-accumulation",
            1000,
            600 + v as u64,
            |r| {
                let ws: Vec<i64> = (0..kw).map(|_| r.range_i64(-lim, lim - 1)).collect();
                let xs: Vec<i64> = (0..7).map(|_| r.range_i64(-lim, lim - 1)).collect();
                (ws, xs)
            },
            |(ws, xs)| {
                let t = pack_approx(&layout, ws)?;
                let vals = t.values();
                let pt = PreparedTuple::prepare(&t);
                let lanes = BatchLanes::pack_lane0(&layout, xs);
                let mut acc = vec![0i64; kw * xs.len()];
                batch.accumulate_lane0(&pt, &lanes, &mut scratch, &mut acc, 0, xs.len(), kw);
                for (j, &wv) in vals.iter().enumerate() {
                    for (g, &x) in xs.iter().enumerate() {
                        let got = acc[j * xs.len() + g];
                        if got != wv * x {
                            return Err(format!(
                                "slot {j} input {x}: {got} != {}",
                                wv * x
                            )
                            .into());
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
