//! Integration: systolic-array simulator vs the CNN reference across
//! architectures, bit widths, and layer geometries (grouped, strided,
//! padded, depthwise).

use sdmm::cnn::infer::{approximate_weights, conv2d_int, Tensor3};
use sdmm::cnn::zoo::ConvLayer;
use sdmm::sa::{PeArch, SaConfig, SystolicArray};
use sdmm::util::rng::Rng;

fn setup(layer: &ConvLayer, v: u32, seed: u64) -> (Vec<i64>, Tensor3) {
    let mut rng = Rng::new(seed);
    let lim = 1i64 << (v - 1);
    let w = (0..layer.params()).map(|_| rng.range_i64(-lim, lim - 1)).collect();
    let mut input = Tensor3::zeros(layer.in_ch, layer.in_hw, layer.in_hw);
    input.data = (0..input.data.len()).map(|_| rng.range_i64(-lim, lim - 1)).collect();
    (w, input)
}

#[test]
fn mp_matches_golden_across_geometries() {
    let geometries = [
        ConvLayer::new("stride2", 8, 3, 6, 3, 2, 1, 1),
        ConvLayer::new("1x1", 5, 8, 9, 1, 1, 0, 1),
        ConvLayer::new("grouped", 6, 4, 6, 3, 1, 1, 2),
        ConvLayer::new("depthwise", 6, 4, 4, 3, 1, 1, 4),
        ConvLayer::new("5x5", 7, 2, 3, 5, 1, 2, 1),
        ConvLayer::new("nopad", 6, 3, 3, 3, 1, 0, 1),
    ];
    for v in [8u32, 6, 4] {
        let sa = SystolicArray::new(SaConfig::paper_prototype(v, PeArch::MultiPack)).unwrap();
        for layer in &geometries {
            let (w, input) = setup(layer, v, 11);
            let run = sa.run_conv(layer, &w, &input).unwrap();
            let golden = conv2d_int(&input, &approximate_weights(&w, v), layer);
            assert_eq!(
                run.output.unwrap(),
                golden,
                "v={v} layer={}",
                layer.name
            );
        }
    }
}

#[test]
fn batch_engine_matches_scalar_across_geometries() {
    // The lane-parallel batch path must agree with the port-accurate
    // scalar path on outputs AND op accounting for every geometry
    // (grouped, strided, padded, depthwise) at every bit width.
    let geometries = [
        ConvLayer::new("stride2", 8, 3, 6, 3, 2, 1, 1),
        ConvLayer::new("1x1", 5, 8, 9, 1, 1, 0, 1),
        ConvLayer::new("grouped", 6, 4, 6, 3, 1, 1, 2),
        ConvLayer::new("depthwise", 6, 4, 4, 3, 1, 1, 4),
        ConvLayer::new("5x5", 7, 2, 3, 5, 1, 2, 1),
        ConvLayer::new("nopad", 6, 3, 3, 3, 1, 0, 1),
    ];
    for v in [8u32, 6, 4] {
        let sa = SystolicArray::new(SaConfig::paper_prototype(v, PeArch::MultiPack)).unwrap();
        for layer in &geometries {
            let (w, input) = setup(layer, v, 15);
            let scalar = sa.run_conv(layer, &w, &input).unwrap();
            let batch = sa.run_conv_batch(layer, &w, &input).unwrap();
            assert_eq!(batch.output, scalar.output, "v={v} layer={}", layer.name);
            assert_eq!(batch.dsp_ops, scalar.dsp_ops, "v={v} layer={}", layer.name);
            assert_eq!(batch.mults, scalar.mults, "v={v} layer={}", layer.name);
        }
    }
}

#[test]
fn one_mac_is_exact_everywhere() {
    let layer = ConvLayer::new("t", 7, 3, 5, 3, 1, 1, 1);
    for v in [8u32, 6, 4] {
        let sa = SystolicArray::new(SaConfig::paper_prototype(v, PeArch::OneMac)).unwrap();
        let (w, input) = setup(&layer, v, 12);
        let run = sa.run_conv(&layer, &w, &input).unwrap();
        assert_eq!(run.output.unwrap(), conv2d_int(&input, &w, &layer));
    }
}

#[test]
fn approximation_error_bounded_at_layer_level() {
    // MP output vs EXACT-weight output: bounded by sum of |dW|·|I|.
    let layer = ConvLayer::new("t", 6, 4, 6, 3, 1, 1, 1);
    let (w, input) = setup(&layer, 8, 13);
    let sa = SystolicArray::new(SaConfig::paper_prototype(8, PeArch::MultiPack)).unwrap();
    let run = sa.run_conv(&layer, &w, &input).unwrap();
    let exact = conv2d_int(&input, &w, &layer);
    let out = run.output.unwrap();
    let max_dw = 4i64; // worst 8-bit approximation error (tested in manip)
    let bound = max_dw * 128 * (layer.in_ch * layer.kernel * layer.kernel) as i64;
    for (a, b) in out.data.iter().zip(&exact.data) {
        assert!((a - b).abs() <= bound, "{a} vs {b}");
    }
}

#[test]
fn cycle_model_consistency() {
    // cycles scale ~linearly in MACs for same-shape layers; utilization
    // bounded by 1; MP and 1M have identical cycle counts (same lane
    // grid) but MP uses 1/3 the DSPs.
    let small = ConvLayer::new("s", 13, 64, 64, 3, 1, 1, 1);
    let big = ConvLayer::new("b", 13, 64, 128, 3, 1, 1, 1);
    let mp = SystolicArray::new(SaConfig::paper_prototype(8, PeArch::MultiPack)).unwrap();
    let m1 = SystolicArray::new(SaConfig::paper_prototype(8, PeArch::OneMac)).unwrap();
    let es = mp.estimate_layer(&small);
    let eb = mp.estimate_layer(&big);
    assert!(eb.cycles > es.cycles);
    let ratio = eb.cycles as f64 / es.cycles as f64;
    assert!((ratio - 2.0).abs() < 0.2, "cycle ratio {ratio}");
    let cfg = SaConfig::paper_prototype(8, PeArch::MultiPack);
    assert!(es.utilization(&cfg) <= 1.0);
    assert_eq!(m1.estimate_layer(&small).cycles, es.cycles);
}

#[test]
fn traffic_accounting_sane() {
    let layer = ConvLayer::new("t", 13, 32, 48, 3, 1, 1, 1);
    let sa = SystolicArray::new(SaConfig::paper_prototype(8, PeArch::MultiPack)).unwrap();
    let est = sa.estimate_layer(&layer);
    let t = est.traffic;
    // every output written once
    assert_eq!(t.omem_writes, 48 * 13 * 13);
    // WRC weight stream: 16 bits per 3 weights
    assert_eq!(
        t.offchip_weight_bits,
        (layer.params().div_ceil(3)) * 16
    );
    assert!(t.imem_reads > 0 && t.wmem_reads > 0);
}

#[test]
fn toggles_accumulate_for_power_model() {
    let layer = ConvLayer::new("t", 5, 2, 3, 3, 1, 1, 1);
    let (w, input) = setup(&layer, 8, 14);
    let sa = SystolicArray::new(SaConfig::paper_prototype(8, PeArch::MultiPack)).unwrap();
    let run = sa.run_conv(&layer, &w, &input).unwrap();
    assert!(run.toggles.ops > 0);
    assert!(run.toggles.p_toggles > 0);
}
