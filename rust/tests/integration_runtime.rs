//! Integration: the Rust runtime against the real AOT artifacts.
//!
//! Requires `make artifacts`. Tests are skipped (with a loud marker)
//! when the artifacts are absent so `cargo test` stays usable on a
//! fresh checkout; CI (`make test`) always builds artifacts first.

use sdmm::runtime::{artifacts_available, exec, Artifacts, CnnModel, WeightMode};

fn artifacts_dir() -> Option<String> {
    // tests run from the crate root
    let dir = "artifacts".to_string();
    if artifacts_available(&dir) {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_and_weights_load() {
    let Some(dir) = artifacts_dir() else { return };
    let a = Artifacts::load(&dir).unwrap();
    assert_eq!(a.shape("conv1_w").unwrap(), vec![8, 1, 3, 3]);
    assert_eq!(a.shape("fc_w").unwrap(), vec![10, 128]);
    let acc = a.meta_f64("train_accuracy").unwrap();
    assert!(acc > 0.8, "trained accuracy {acc}");
}

#[test]
fn cnn_forward_executes_and_classifies() {
    let Some(dir) = artifacts_dir() else { return };
    let a = Artifacts::load(&dir).unwrap();
    let client = exec::Client::cpu().unwrap();
    let model = CnnModel::load(&client, &a).unwrap();
    let staged = model.stage(WeightMode::Float).unwrap();

    let xs = a.f32("eval_x").unwrap();
    let ys = a.i32("eval_y").unwrap();
    let item = model.input_hw * model.input_hw;
    let batch = model.batch;

    let mut correct = 0usize;
    let mut total = 0usize;
    for b in 0..8 {
        let x = &xs[b * batch * item..(b + 1) * batch * item];
        let logits = model.infer(&staged, x).unwrap();
        let preds = model.argmax_rows(&logits);
        for (i, p) in preds.iter().enumerate() {
            if *p as i32 == ys[b * batch + i] {
                correct += 1;
            }
            total += 1;
        }
    }
    let acc = correct as f64 / total as f64;
    // must reproduce the training-time eval accuracy (same data)
    let trained = a.meta_f64("train_accuracy").unwrap();
    assert!(
        (acc - trained).abs() < 0.08,
        "PJRT accuracy {acc} vs python {trained}"
    );
}

#[test]
fn quantized_and_approximated_modes_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let a = Artifacts::load(&dir).unwrap();
    let client = exec::Client::cpu().unwrap();
    let model = CnnModel::load(&client, &a).unwrap();

    let xs = a.f32("eval_x").unwrap();
    let item = model.input_hw * model.input_hw;
    let x = &xs[..model.batch * item];

    for mode in [
        WeightMode::Quantized { w_bits: 8 },
        WeightMode::Approximated { w_bits: 8 },
        WeightMode::Approximated { w_bits: 4 },
    ] {
        let staged = model.stage(mode).unwrap();
        let logits = model.infer(&staged, x).unwrap();
        assert_eq!(logits.len(), model.batch * model.num_classes);
        assert!(logits.iter().all(|v| v.is_finite()), "{mode:?}");
    }
}

#[test]
fn weight_modes_differ_only_where_expected() {
    let Some(dir) = artifacts_dir() else { return };
    let a = Artifacts::load(&dir).unwrap();
    let client = exec::Client::cpu().unwrap();
    let model = CnnModel::load(&client, &a).unwrap();
    // 4-bit: approximation is exact => quantized and approximated
    // weights must be IDENTICAL (paper §3.2).
    let wq = model.weights_for_mode(WeightMode::Quantized { w_bits: 4 });
    let wa = model.weights_for_mode(WeightMode::Approximated { w_bits: 4 });
    assert_eq!(wq, wa, "4-bit approximation must be lossless");
    // 8-bit: some weights move.
    let wq8 = model.weights_for_mode(WeightMode::Quantized { w_bits: 8 });
    let wa8 = model.weights_for_mode(WeightMode::Approximated { w_bits: 8 });
    assert_ne!(wq8, wa8, "8-bit approximation should alter some weights");
}

#[test]
fn pallas_kernel_artifact_matches_rust_dsp_model() {
    // THE cross-layer equivalence: the HLO lowered from the Pallas
    // kernel (L1), executed via PJRT from Rust (L3), must agree with
    // the bit-accurate DSP48E1 model on the same packed problem — and
    // with the python-side oracle output stored in the artifacts.
    let Some(dir) = artifacts_dir() else { return };
    let a = Artifacts::load(&dir).unwrap();
    let client = exec::Client::cpu().unwrap();
    let exe = exec::Executable::load(&client, a.hlo_path("sdmm_gemm").unwrap()).unwrap();

    let names = ["gemm_x", "gemm_a_words", "gemm_n", "gemm_s", "gemm_zero", "gemm_neg"];
    let mut args = Vec::new();
    for n in names {
        let data = a.i32(n).unwrap();
        let shape = a.shape(n).unwrap();
        args.push(exec::literal_i32(&data, &shape).unwrap());
    }
    let out = exe.execute_i32(&args).unwrap();
    let want = a.i32("gemm_out").unwrap();
    assert_eq!(out, want, "PJRT sdmm_gemm != python oracle");

    // Now the Rust DSP model on the same problem.
    let x = a.i32("gemm_x").unwrap();
    let xs = a.shape("gemm_x").unwrap(); // [B, K]
    let aw = a.i32("gemm_a_words").unwrap();
    let n_ = a.i32("gemm_n").unwrap();
    let s_ = a.i32("gemm_s").unwrap();
    let z_ = a.i32("gemm_zero").unwrap();
    let g_ = a.i32("gemm_neg").unwrap();
    let (b, k) = (xs[0], xs[1]);
    let mg = a.shape("gemm_a_words").unwrap()[0];

    let layout = sdmm::packing::Layout::for_bits(8).unwrap();
    let mut engine = sdmm::dsp::SdmmEngine::new();
    let mut rust_out = vec![0i32; b * mg * 3];
    for bi in 0..b {
        for g in 0..mg {
            for kk in 0..k {
                // rebuild the tuple from the control arrays
                // control layout: [MG, 3, K] flattened
                let idx3 = |j: usize| (g * 3 + j) * k + kk;
                let weights: Vec<i64> = (0..3)
                    .map(|j| {
                        let zero = z_[idx3(j)] == 1;
                        if zero {
                            0
                        } else {
                            let mwv = (aw[g * k + kk] >> (11 * j)) & 7;
                            let mag = (1i64 + ((mwv as i64) << n_[idx3(j)])) << s_[idx3(j)];
                            if g_[idx3(j)] == 1 {
                                -mag
                            } else {
                                mag
                            }
                        }
                    })
                    .collect();
                let tuple = sdmm::packing::pack_approx(&layout, &weights).unwrap();
                let prods = engine.execute(&tuple, &[x[bi * k + kk] as i64]);
                for j in 0..3 {
                    rust_out[bi * mg * 3 + g * 3 + j] += prods[j][0] as i32;
                }
            }
        }
    }
    assert_eq!(rust_out, want, "rust DSP model != python oracle");
}
