//! Facade tests: the four `Executor` backends are interchangeable and
//! bit-exact, and every failure path is a typed `SdmmError` — never a
//! panic.
//!
//! The equivalence property runs randomized 8/6/4-bit layers through
//! `ScalarExec`, `BatchExec`, `SystolicExec` and `ServingExec` and
//! requires bit-identical outputs *and* op accounting, plus agreement
//! with the golden integer convolution over the approximated weights.

use sdmm::api::{
    ApproxPolicy, BatchExec, CompiledModel, Compiler, Executor, ScalarExec, ServingExec,
    SystolicExec,
};
use sdmm::cnn::infer::{approximate_weights, conv2d_int, relu, requantize, Tensor3};
use sdmm::cnn::zoo::ConvLayer;
use sdmm::coordinator::ServingConfig;
use sdmm::dsp::BatchLanes;
use sdmm::error::SdmmError;
use sdmm::packing::{pack_approx, Layout};
use sdmm::util::check::check;
use sdmm::util::rng::Rng;

/// Random small conv layer + in-range weights + input at width `v`.
fn random_case(r: &mut Rng, v: u32) -> (ConvLayer, Vec<i64>, Tensor3) {
    let in_hw = 4 + r.below(4) as usize; // 4..8
    let in_ch = 1 + r.below(4) as usize; // 1..5
    let out_ch = 1 + r.below(7) as usize; // 1..8
    let kernel = if r.bool(0.5) { 3 } else { 1 };
    let pad = if kernel == 3 && r.bool(0.5) { 1 } else { 0 };
    let layer = ConvLayer::new("p", in_hw, in_ch, out_ch, kernel, 1, pad, 1);
    let lim = 1i64 << (v - 1);
    let weights: Vec<i64> = (0..layer.params()).map(|_| r.range_i64(-lim, lim - 1)).collect();
    let mut input = Tensor3::zeros(in_ch, in_hw, in_hw);
    input.data = (0..input.data.len()).map(|_| r.range_i64(-lim, lim - 1)).collect();
    (layer, weights, input)
}

/// Golden reference: integer conv over the approximated weights, then
/// the facade's ReLU + requantize glue.
fn golden(layer: &ConvLayer, weights: &[i64], input: &Tensor3, v: u32) -> Tensor3 {
    let mut y = conv2d_int(input, &approximate_weights(weights, v), layer);
    relu(&mut y);
    requantize(&y, v).0
}

fn compile(layer: &ConvLayer, weights: &[i64], v: u32) -> CompiledModel {
    Compiler::for_bits(v)
        .unwrap()
        .approximate(ApproxPolicy::nearest())
        .pack_model("prop", &[layer.clone()], &[weights.to_vec()])
        .unwrap()
}

#[test]
fn prop_all_executors_bit_identical() {
    let mut serving = ServingExec::start(ServingConfig {
        shards: 2,
        queue_capacity: 16,
    })
    .unwrap();
    for v in [8u32, 6, 4] {
        let mut scalar = ScalarExec::new();
        let mut batch = BatchExec::new();
        let mut systolic = SystolicExec::new();
        check(
            "executors-bit-identical",
            10,
            700 + v as u64,
            |r| random_case(r, v),
            |(layer, weights, input)| {
                let model = compile(layer, weights, v);
                let a = scalar.run(&model, input)?;
                let b = batch.run(&model, input)?;
                let c = systolic.run(&model, input)?;
                let d = serving.run(&model, input)?;
                let want = golden(layer, weights, input, v);
                for (name, out) in [("scalar", &a), ("batch", &b), ("systolic", &c), ("serving", &d)]
                {
                    if out.output != want {
                        return Err(format!("{name} output != golden conv (v={v})").into());
                    }
                }
                if (a.dsp_ops, a.mults) != (b.dsp_ops, b.mults)
                    || (b.dsp_ops, b.mults) != (c.dsp_ops, c.mults)
                    || (c.dsp_ops, c.mults) != (d.dsp_ops, d.mults)
                {
                    return Err(format!(
                        "op accounting diverged (v={v}): scalar ({}, {}), batch ({}, {}), \
                         systolic ({}, {}), serving ({}, {})",
                        a.dsp_ops, a.mults, b.dsp_ops, b.mults, c.dsp_ops, c.mults, d.dsp_ops,
                        d.mults
                    )
                    .into());
                }
                if a.mults != layer.macs() {
                    return Err(format!("mults {} != layer macs {}", a.mults, layer.macs()).into());
                }
                Ok(())
            },
        );
    }
    let snap = serving.shutdown();
    assert!(snap.total_jobs() > 0);
    assert_eq!(snap.total_failed(), 0);
}

#[test]
fn unsupported_bit_width_is_typed() {
    for v in [0u32, 5, 7, 12] {
        assert!(matches!(
            Compiler::for_bits(v),
            Err(SdmmError::UnsupportedBitWidth { v: got }) if got == v
        ));
        // The same error propagates through layout lookup and serving
        // admission instead of aborting a worker.
        assert!(matches!(
            Layout::for_bits(v),
            Err(SdmmError::UnsupportedBitWidth { .. })
        ));
    }
}

#[test]
fn out_of_range_weight_is_typed() {
    let c = Compiler::for_bits(8).unwrap().approximate(ApproxPolicy::nearest());
    assert!(matches!(
        c.pack_tuple(&[129, 0, 0]),
        Err(SdmmError::WeightOutOfRange { weight: 129, c_bits: 8 })
    ));
    let layout = Layout::for_bits(8).unwrap();
    assert!(matches!(
        pack_approx(&layout, &[0, -300, 0]),
        Err(SdmmError::WeightOutOfRange { weight: -300, c_bits: 8 })
    ));
    // wrong arity is typed too (used to be the panic path)
    assert!(matches!(
        pack_approx(&layout, &[1, 2]),
        Err(SdmmError::ArityMismatch { got: 2, expected: 3, .. })
    ));
}

#[test]
fn batch_lane_arity_is_typed_not_a_panic() {
    let layout = Layout::for_bits(4).unwrap(); // ki = 3
    assert!(matches!(
        BatchLanes::pack(&layout, &[1, 2, 3, 4]),
        Err(SdmmError::NotAMultiple { len: 4, multiple_of: 3, .. })
    ));
    assert!(BatchLanes::pack(&layout, &[1, 2, 3, 4, 5, 6]).is_ok());
}

#[test]
fn pack_model_keeps_typed_source_behind_context() {
    let c = Compiler::for_bits(8).unwrap().approximate(ApproxPolicy::nearest());
    let layer = ConvLayer::new("c1", 6, 2, 3, 3, 1, 1, 1);
    let mut w = vec![0i64; layer.params() as usize];
    w[7] = 300;
    let err = c.pack_model("m", &[layer], &[w]).unwrap_err();
    // the message says where, the root stays dispatchable
    assert!(err.to_string().contains("packing model m layer 0"));
    assert!(matches!(
        err.root(),
        SdmmError::WeightOutOfRange { weight: 300, c_bits: 8 }
    ));
}

#[test]
fn registry_rejects_hand_assembled_scalar_only_planes() {
    use sdmm::coordinator::ModelRegistry;
    use sdmm::packing::PackedPlane;
    let layer = ConvLayer::new("c1", 6, 2, 3, 3, 1, 1, 1);
    let w = vec![1i64; layer.params() as usize];
    let layout = Layout::for_bits(8).unwrap();
    let plane = PackedPlane::build_scalar(&layout, 3, &w, &layer).unwrap();
    let model = CompiledModel {
        name: "hand".into(),
        v_bits: 8,
        group: 3,
        compression: sdmm::api::CompressionPolicy::None,
        wrom: None,
        layers: vec![sdmm::api::CompiledLayer {
            layer,
            plane: std::sync::Arc::new(plane),
            stats: sdmm::manip::approximation_error_table(&[], 8),
            compressed: None,
        }],
    };
    // a scalar-only plane would panic a shard worker mid-conv; the
    // registry must refuse it at the door instead
    let reg = ModelRegistry::new();
    assert!(matches!(
        reg.register_compiled(&model),
        Err(SdmmError::InvalidModel(_))
    ));
}

#[test]
fn shape_and_range_mismatches_are_typed_on_every_executor() {
    let layer = ConvLayer::new("c1", 6, 2, 3, 3, 1, 1, 1);
    let weights: Vec<i64> = vec![1; layer.params() as usize];
    let model = compile(&layer, &weights, 8);

    let wrong_shape = Tensor3::zeros(3, 6, 6);
    let mut hot = Tensor3::zeros(2, 6, 6);
    hot.data[0] = 4096; // outside signed 8-bit

    let mut serving = ServingExec::start(ServingConfig {
        shards: 1,
        queue_capacity: 4,
    })
    .unwrap();
    let mut scalar = ScalarExec::new();
    let mut batch = BatchExec::new();
    let mut systolic = SystolicExec::new();
    let execs: [&mut dyn Executor; 4] = [&mut scalar, &mut batch, &mut systolic, &mut serving];
    for e in execs {
        assert!(
            matches!(
                e.run(&model, &wrong_shape),
                Err(SdmmError::ShapeMismatch {
                    expected: (2, 6, 6),
                    got: (3, 6, 6)
                })
            ),
            "{} shape mismatch not typed",
            e.name()
        );
        assert!(
            matches!(
                e.run(&model, &hot),
                Err(SdmmError::InputOutOfRange { v_bits: 8 })
            ),
            "{} range violation not typed",
            e.name()
        );
    }
}

#[test]
fn registry_admission_propagates_layout_errors() {
    use sdmm::coordinator::{ModelRegistry, ModelSpec};
    let reg = ModelRegistry::new();
    let mut spec = ModelSpec::random(
        "odd",
        8,
        vec![ConvLayer::new("c1", 6, 2, 3, 3, 1, 1, 1)],
        9,
    );
    spec.v_bits = 5; // no layout for 5-bit operands
    assert!(matches!(
        reg.register(spec),
        Err(SdmmError::UnsupportedBitWidth { v: 5 })
    ));
    assert!(reg.is_empty());
}

#[test]
fn exact_policy_packs_tuples_but_not_planes() {
    let exact = Compiler::for_bits(8).unwrap().approximate(ApproxPolicy::exact());
    assert_eq!(exact.pack_tuple(&[7, 64, -96]).unwrap().values(), vec![7, 64, -96]);
    let layer = ConvLayer::new("c1", 6, 2, 3, 3, 1, 1, 1);
    let w = vec![1i64; layer.params() as usize];
    assert!(matches!(
        exact.pack(&layer, &w),
        Err(SdmmError::UnsupportedBackend(_))
    ));
}

#[test]
fn serving_exec_reuses_registered_planes() {
    let layer = ConvLayer::new("c1", 6, 2, 3, 3, 1, 1, 1);
    let mut rng = Rng::new(77);
    let weights: Vec<i64> = (0..layer.params()).map(|_| rng.range_i64(-128, 127)).collect();
    let model = compile(&layer, &weights, 8);
    let mut serving = ServingExec::start(ServingConfig {
        shards: 1,
        queue_capacity: 4,
    })
    .unwrap();
    let input = Tensor3::zeros(2, 6, 6);
    serving.run(&model, &input).unwrap();
    let registered = serving.registry().get(&model.key()).unwrap();
    // the registry shares the compiled plane, it does not repack
    assert!(std::sync::Arc::ptr_eq(registered.plane(0), &model.layers[0].plane));
    serving.run(&model, &input).unwrap();
    let again = serving.registry().get(&model.key()).unwrap();
    assert!(std::sync::Arc::ptr_eq(&registered, &again));
    let snap = serving.shutdown();
    assert_eq!(snap.total_jobs(), 2);
}
