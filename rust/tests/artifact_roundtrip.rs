//! Artifact round-trip suite: `save → load → run` must be bit-exact
//! with the in-memory compiled model for every `CompressionPolicy` at
//! every bit width, on both the port-accurate scalar backend and the
//! lane-parallel batch backend; measured WRC stream sizes must match
//! the paper's guaranteed rates; the registry must serve a cold-loaded
//! artifact identically to an in-process-compiled one; and corrupted /
//! truncated artifacts must yield typed errors, never panics.

use sdmm::api::{
    ApproxPolicy, BatchExec, CompiledModel, Compiler, CompressionPolicy, Executor, ScalarExec,
};
use sdmm::cnn::infer::Tensor3;
use sdmm::cnn::zoo::ConvLayer;
use sdmm::coordinator::ModelRegistry;
use sdmm::error::SdmmError;
use sdmm::sa::{PeArch, SaConfig, SystolicArray};
use sdmm::util::rng::Rng;
use std::path::{Path, PathBuf};

const POLICIES: [CompressionPolicy; 4] = [
    CompressionPolicy::None,
    CompressionPolicy::Wrc,
    CompressionPolicy::WrcHuffman,
    CompressionPolicy::PruneWrcHuffman,
];

/// Self-cleaning temp dir (no tempdir crate in the vendored set).
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let p = std::env::temp_dir().join(format!(
            "sdmm-roundtrip-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        TempDir(p)
    }

    fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// out_ch = 12 is a whole number of DSP groups at every bit width
/// (3/4/6), so the WRC stream carries no channel padding and the rate
/// shows the exact guarantee.
fn demo_layers() -> Vec<ConvLayer> {
    vec![
        ConvLayer::new("r1", 8, 5, 12, 3, 1, 1, 1),
        ConvLayer::new("r2", 8, 12, 12, 3, 1, 1, 1),
    ]
}

/// Trained-net regime weights (heavy-tailed), the distribution the
/// Huffman columns of Table 3 assume.
fn laplacian_weights(layers: &[ConvLayer], bits: u32, seed: u64) -> Vec<Vec<i64>> {
    let lim = (1i64 << (bits - 1)) - 1;
    let b = (lim as f64 / 25.0).max(0.6);
    let mut rng = Rng::new(seed);
    layers
        .iter()
        .map(|l| {
            (0..l.params())
                .map(|_| rng.laplace(b).round().clamp(-(lim + 1) as f64, lim as f64) as i64)
                .collect()
        })
        .collect()
}

fn compile(bits: u32, policy: CompressionPolicy, seed: u64) -> CompiledModel {
    let layers = demo_layers();
    let weights = laplacian_weights(&layers, bits, seed);
    Compiler::for_bits(bits)
        .unwrap()
        .approximate(ApproxPolicy::nearest())
        .compress(policy)
        .pack_model("rt", &layers, &weights)
        .unwrap()
}

fn rand_input(model: &CompiledModel, seed: u64) -> Tensor3 {
    let (c, h, w) = model.input_shape();
    let lim = 1i64 << (model.v_bits - 1);
    let mut rng = Rng::new(seed);
    let mut t = Tensor3::zeros(c, h, w);
    t.data = (0..t.data.len()).map(|_| rng.range_i64(-lim, lim - 1)).collect();
    t
}

#[test]
fn round_trip_bit_exact_for_every_policy_and_width() {
    for v in [8u32, 6, 4] {
        for policy in POLICIES {
            for seed in [1u64, 2] {
                let model = compile(v, policy, 100 * seed + v as u64);
                let dir = TempDir::new(&format!("rt-{v}-{}-{seed}", policy.tag()));
                model.save(dir.path()).unwrap();
                let loaded = CompiledModel::load(dir.path()).unwrap();

                assert_eq!(loaded.name, model.name);
                assert_eq!(loaded.v_bits, model.v_bits);
                assert_eq!(loaded.group, model.group);
                assert_eq!(loaded.compression, policy);
                assert_eq!(loaded.layers.len(), model.layers.len());
                for (a, b) in model.layers.iter().zip(&loaded.layers) {
                    assert_eq!(a.layer, b.layer);
                    // tuple-level identity: the decode path rebuilt the
                    // exact packed representation, not a re-approximation
                    assert_eq!(a.plane.tiles.len(), b.plane.tiles.len());
                    for (ta, tb) in a.plane.tiles.iter().zip(&b.plane.tiles) {
                        assert_eq!(ta.tuples, tb.tuples, "v={v} policy={policy} seed={seed}");
                    }
                    assert_eq!(
                        a.effective_weights(),
                        b.effective_weights(),
                        "v={v} policy={policy}"
                    );
                }

                // load -> save must re-serialize byte-identically: the
                // writer emits the stored book/RLE/stream parts, never a
                // re-derivation that could drift
                if seed == 1 {
                    let dir2 = TempDir::new(&format!("rt2-{v}-{}", policy.tag()));
                    loaded.save(dir2.path()).unwrap();
                    let a = std::fs::read(dir.path().join("sdmm-model.bin")).unwrap();
                    let b = std::fs::read(dir2.path().join("sdmm-model.bin")).unwrap();
                    assert_eq!(a, b, "re-serialization drifted (v={v} policy={policy})");
                }

                let input = rand_input(&model, 900 + seed);
                let s1 = ScalarExec::new().run(&model, &input).unwrap();
                let s2 = ScalarExec::new().run(&loaded, &input).unwrap();
                assert_eq!(s1.output, s2.output, "scalar v={v} policy={policy}");
                assert_eq!((s1.dsp_ops, s1.mults), (s2.dsp_ops, s2.mults));
                let b1 = BatchExec::new().run(&model, &input).unwrap();
                let b2 = BatchExec::new().run(&loaded, &input).unwrap();
                assert_eq!(b1.output, b2.output, "batch v={v} policy={policy}");
                assert_eq!((b1.dsp_ops, b1.mults), (b2.dsp_ops, b2.mults));
                assert_eq!(s1.output, b1.output);
            }
        }
    }
}

#[test]
fn wrc_artifact_rate_matches_paper_guarantee() {
    for (v, pct) in [(8u32, 66.67), (6, 75.0), (4, 83.33)] {
        let model = compile(v, CompressionPolicy::Wrc, 7);
        let rate = model.compression_rate().unwrap();
        assert!(
            (rate.percent() - pct).abs() < 0.5,
            "v={v}: measured {} vs guaranteed {pct}",
            rate.percent()
        );
        // the saved artifact reports the same measured rate
        let dir = TempDir::new(&format!("rate-{v}"));
        let info = model.save(dir.path()).unwrap();
        let stored = info.rate.unwrap();
        assert_eq!(stored.compressed_bits, rate.compressed_bits);
        assert_eq!(stored.original_bits, rate.original_bits);
    }
}

/// A model big and peaky enough that the Huffman code book amortizes —
/// tiny uniform-ish models make `WRC + H` lose to plain WRC on book
/// overhead alone (same reason Table 3 uses whole networks).
fn compile_big(policy: CompressionPolicy) -> CompiledModel {
    let layers = vec![
        ConvLayer::new("b1", 4, 16, 48, 3, 1, 1, 1),
        ConvLayer::new("b2", 4, 48, 48, 3, 1, 1, 1),
    ];
    let mut rng = Rng::new(88);
    let weights: Vec<Vec<i64>> = layers
        .iter()
        .map(|l| {
            (0..l.params())
                .map(|_| rng.laplace(1.0).round().clamp(-128.0, 127.0) as i64)
                .collect()
        })
        .collect();
    Compiler::for_bits(8)
        .unwrap()
        .approximate(ApproxPolicy { skip_stats: true, ..ApproxPolicy::nearest() })
        .compress(policy)
        .pack_model("big", &layers, &weights)
        .unwrap()
}

#[test]
fn composed_policies_compress_beyond_wrc() {
    let r_wrc = compile_big(CompressionPolicy::Wrc).compression_rate().unwrap().percent();
    let r_wh = compile_big(CompressionPolicy::WrcHuffman)
        .compression_rate()
        .unwrap()
        .percent();
    let r_p = compile_big(CompressionPolicy::PruneWrcHuffman)
        .compression_rate()
        .unwrap()
        .percent();
    assert!(r_wh < r_wrc, "WRC+H {r_wh} !< WRC {r_wrc}");
    assert!(r_p < r_wrc, "P+WRC+H {r_p} !< WRC {r_wrc}");
}

#[test]
fn pruned_policy_round_trips_the_pruned_network() {
    let model = compile(8, CompressionPolicy::PruneWrcHuffman, 9);
    let eff: Vec<i64> = model.layers.iter().flat_map(|l| l.effective_weights()).collect();
    let zeros = eff.iter().filter(|&&w| w == 0).count();
    // default sparsity 0.65: the compiled model IS the pruned network
    assert!(
        zeros as f64 > 0.5 * eff.len() as f64,
        "{zeros}/{} zeros",
        eff.len()
    );
    let dir = TempDir::new("pruned");
    model.save(dir.path()).unwrap();
    let loaded = CompiledModel::load(dir.path()).unwrap();
    let eff2: Vec<i64> = loaded.layers.iter().flat_map(|l| l.effective_weights()).collect();
    assert_eq!(eff, eff2);
}

#[test]
fn registry_serves_cold_loaded_artifact_identically() {
    let model = compile(8, CompressionPolicy::WrcHuffman, 10);
    let dir = TempDir::new("cold");
    model.save(dir.path()).unwrap();

    // in-process admission vs cold-load admission, two registries
    let warm = ModelRegistry::new();
    warm.register_compiled(&model).unwrap();
    let cold = ModelRegistry::new();
    let cold_model = cold.register_from_artifact(dir.path()).unwrap();
    assert_eq!(cold_model.key, model.key());
    assert!(cold.plane("rt", 0, 8).is_some());

    let sa = SystolicArray::new(SaConfig::paper_prototype(8, PeArch::MultiPack)).unwrap();
    for seed in [20u64, 21, 22] {
        let input = rand_input(&model, seed);
        let a = warm.get(&model.key()).unwrap().run(&sa, &input).unwrap();
        let b = cold_model.run(&sa, &input).unwrap();
        assert_eq!(a.output, b.output, "cold-loaded serve diverged (seed {seed})");
        assert_eq!((a.dsp_ops, a.mults), (b.dsp_ops, b.mults));
    }
}

/// FNV-1a 64 (mirror of the store's footer hash, so tests can corrupt
/// a field and re-seal the file to exercise the deep validation paths).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Corrupt `bin` with `mutate`, re-seal checksum footer + manifest.
fn corrupt_and_reseal(dir: &Path, mutate: impl FnOnce(&mut Vec<u8>)) {
    let bin_path = dir.join("sdmm-model.bin");
    let mut bytes = std::fs::read(&bin_path).unwrap();
    let old_sum = format!("{:016x}", fnv1a64(&bytes[..bytes.len() - 8]));
    bytes.truncate(bytes.len() - 8);
    mutate(&mut bytes);
    let new_sum = fnv1a64(&bytes);
    bytes.extend_from_slice(&new_sum.to_le_bytes());
    std::fs::write(&bin_path, &bytes).unwrap();
    let manifest_path = dir.join("manifest.json");
    let manifest = std::fs::read_to_string(&manifest_path).unwrap();
    std::fs::write(
        &manifest_path,
        manifest.replace(&old_sum, &format!("{new_sum:016x}")),
    )
    .unwrap();
}

fn assert_corrupt(err: SdmmError) {
    assert!(
        matches!(err.root(), SdmmError::CorruptArtifact(_)),
        "expected CorruptArtifact, got: {err}"
    );
}

#[test]
fn truncated_artifacts_yield_typed_errors() {
    let model = compile(8, CompressionPolicy::Wrc, 11);
    let dir = TempDir::new("trunc");
    model.save(dir.path()).unwrap();
    let bin_path = dir.path().join("sdmm-model.bin");
    let full = std::fs::read(&bin_path).unwrap();
    for cut in [0usize, 3, 7, 11, full.len() / 3, full.len() / 2, full.len() - 9, full.len() - 1]
    {
        std::fs::write(&bin_path, &full[..cut]).unwrap();
        let err = CompiledModel::load(dir.path()).unwrap_err();
        assert_corrupt(err);
    }
    // restore and confirm it still loads (the writer, not the file
    // system, was under test)
    std::fs::write(&bin_path, &full).unwrap();
    CompiledModel::load(dir.path()).unwrap();
}

#[test]
fn bit_flips_and_fabricated_headers_yield_typed_errors() {
    for policy in [CompressionPolicy::Wrc, CompressionPolicy::PruneWrcHuffman] {
        let model = compile(8, policy, 12);
        let dir = TempDir::new(&format!("flip-{}", policy.tag()));
        let bin_path = dir.path().join("sdmm-model.bin");

        // a raw bit flip mid-file trips the checksum gate
        model.save(dir.path()).unwrap();
        let mut flipped = std::fs::read(&bin_path).unwrap();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x40;
        std::fs::write(&bin_path, &flipped).unwrap();
        assert_corrupt(CompiledModel::load(dir.path()).unwrap_err());

        // a re-sealed bad magic reaches the header validation (fresh
        // save each time: re-sealing rewrites the manifest checksum)
        model.save(dir.path()).unwrap();
        corrupt_and_reseal(dir.path(), |b| b[0] ^= 0xff);
        assert_corrupt(CompiledModel::load(dir.path()).unwrap_err());

        // a re-sealed unknown policy tag is refused, typed
        model.save(dir.path()).unwrap();
        corrupt_and_reseal(dir.path(), |b| b[6] = 9);
        assert_corrupt(CompiledModel::load(dir.path()).unwrap_err());
    }
}

/// Satellite of the fault-tolerance PR: a seeded N=256 mutation sweep
/// over the serialized artifact through the raw-bytes entry point
/// ([`sdmm::runtime::load_model_bytes`]). Every mutation — random bit
/// flips, truncations at arbitrary offsets, and planned
/// [`FaultPlan::corrupt_artifact`] burst corruptions — must come back
/// as a typed `CorruptArtifact`-family error. A panic (or an
/// over-allocation aborting the process) fails the test by
/// construction.
#[test]
fn seeded_mutation_sweep_never_panics_and_always_types_the_error() {
    use sdmm::fault::{FaultPlan, FaultSpec};
    use sdmm::runtime::load_model_bytes;

    let model = compile(8, CompressionPolicy::WrcHuffman, 14);
    let dir = TempDir::new("sweep");
    model.save(dir.path()).unwrap();
    let pristine = std::fs::read(dir.path().join("sdmm-model.bin")).unwrap();
    // The unmutated bytes parse — the sweep mutates a known-good file.
    load_model_bytes(&pristine).unwrap();

    let mut rng = Rng::new(0x5eed);
    for case in 0..256u32 {
        let mut bytes = pristine.clone();
        match case % 4 {
            // 1–8 random single-bit flips anywhere in the file
            // (including the checksum footer).
            0 => {
                let flips = 1 + rng.below(8);
                for _ in 0..flips {
                    let pos = rng.below(bytes.len() as u64) as usize;
                    bytes[pos] ^= 1 << rng.below(8);
                }
            }
            // Truncation at an arbitrary offset (torn write / short
            // read), including the empty file.
            1 => {
                let keep = rng.below(bytes.len() as u64) as usize;
                bytes.truncate(keep);
            }
            // A planned burst corruption from the chaos module's own
            // generator — the same flips `serve-sim --chaos-seed`
            // would apply.
            2 => {
                let spec = FaultSpec::light(1, 8);
                let plan = FaultPlan::generate(1000 + case as u64, &spec);
                assert!(plan.corrupt_artifact(&mut bytes) > 0);
            }
            // A multi-byte stomp: overwrite a random window with seeded
            // garbage (fabricated section data).
            _ => {
                let start = rng.below((bytes.len() - 1) as u64) as usize;
                let len = (1 + rng.below(64) as usize).min(bytes.len() - start);
                for b in &mut bytes[start..start + len] {
                    *b = rng.below(256) as u8;
                }
            }
        }
        if bytes == pristine {
            // A garbage window can coincide with the original bytes;
            // such a case is a no-op, not a corruption.
            continue;
        }
        let err = load_model_bytes(&bytes).unwrap_err();
        assert_corrupt(err);
    }
}

#[test]
fn manifest_mismatch_and_absence_are_typed_errors() {
    let model = compile(8, CompressionPolicy::Wrc, 13);
    let dir = TempDir::new("manifest");
    model.save(dir.path()).unwrap();
    let manifest_path = dir.path().join("manifest.json");
    let manifest = std::fs::read_to_string(&manifest_path).unwrap();

    // manifest that disagrees with the binary header
    std::fs::write(&manifest_path, manifest.replace("\"name\":\"rt\"", "\"name\":\"xx\""))
        .unwrap();
    assert_corrupt(CompiledModel::load(dir.path()).unwrap_err());

    // missing manifest: a typed error (not a panic), message says what
    std::fs::remove_file(&manifest_path).unwrap();
    let err = CompiledModel::load(dir.path()).unwrap_err();
    assert!(err.to_string().contains("manifest"), "{err}");
}
