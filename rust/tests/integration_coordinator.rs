//! Integration: the packing pipeline + serving coordinator, including
//! the PJRT-backed path when artifacts are available.

use sdmm::coordinator::pipeline::PipelineMode;
use sdmm::coordinator::{BatchPolicy, BatchRunner, CnnRunner, InferenceServer, PackingPipeline};
use sdmm::packing::Layout;
use sdmm::util::rng::Rng;
use std::time::Duration;

#[test]
fn packing_pipeline_end_to_end() {
    let mut rng = Rng::new(31);
    let layers: Vec<(String, Vec<f64>)> = (0..4)
        .map(|i| {
            (
                format!("layer{i}"),
                (0..3000).map(|_| rng.laplace(0.04)).collect(),
            )
        })
        .collect();
    for bits in [8u32, 6, 4] {
        let p = PackingPipeline::new(Layout::for_bits(bits).unwrap(), PipelineMode::Approximate);
        let net = p.pack_network(&layers).unwrap();
        let rep = net.report();
        assert_eq!(rep.total_weights, 12_000);
        // guaranteed WRC rates
        let expect = match bits {
            8 => 66.67,
            6 => 75.0,
            _ => 83.33,
        };
        assert!((rep.compression_percent() - expect).abs() < 0.5);
        // every layer decompresses to its effective weights
        for l in &net.layers {
            assert_eq!(net.wrom.decompress(&l.stream), l.effective_weights);
        }
        // WROM fits the paper's address space
        assert!(rep.wrom_entries as u64 <= net.wrom.paper_max_entries());
    }
}

#[test]
fn exact_mode_tunes_tuples() {
    let mut rng = Rng::new(32);
    // heavy-tailed weights: many wide-MW values force fine-tuning
    let layers = vec![(
        "w".to_string(),
        (0..3000)
            .map(|_| if rng.bool(0.5) { rng.f64() - 0.5 } else { rng.laplace(0.3) })
            .collect::<Vec<f64>>(),
    )];
    let p = PackingPipeline::new(Layout::for_bits(8).unwrap(), PipelineMode::ExactFineTuned);
    let net = p.pack_network(&layers).unwrap();
    assert!(net.exact_tuples > 0);
    assert!(
        net.tuned_tuples > 0,
        "expected some tuples to need fine-tuning"
    );
}

/// CPU-only mock runner for coordinator stress (no PJRT needed).
struct SumRunner;

impl BatchRunner for SumRunner {
    fn batch_size(&self) -> usize {
        16
    }
    fn item_len(&self) -> usize {
        8
    }
    fn out_len(&self) -> usize {
        1
    }
    fn run(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        Ok(x.chunks(8).map(|c| c.iter().sum()).collect())
    }
}

#[test]
fn coordinator_under_load_preserves_request_response_pairing() {
    let server = InferenceServer::start(
        SumRunner,
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_micros(300),
        },
    );
    let n = 500;
    let rxs: Vec<_> = (0..n)
        .map(|i| server.submit(vec![i as f32; 8]))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out, vec![8.0 * i as f32], "request {i} got wrong batch slot");
    }
    let m = server.shutdown();
    assert_eq!(m.requests, n as u64);
    assert!(m.latency.p99() > 0.0);
}

#[test]
fn pjrt_backed_server_roundtrip() {
    if !sdmm::runtime::artifacts_available("artifacts") {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let server = InferenceServer::start_factory(
        || CnnRunner::load("artifacts", sdmm::runtime::WeightMode::Approximated { w_bits: 8 }),
        BatchPolicy::default(),
    );
    let art = sdmm::runtime::Artifacts::load("artifacts").unwrap();
    let xs = art.f32("eval_x").unwrap();
    let logits = server.infer(xs[..256].to_vec()).unwrap();
    assert_eq!(logits.len(), 10);
    assert!(logits.iter().all(|v| v.is_finite()));
    let m = server.shutdown();
    assert_eq!(m.requests, 1);
}

#[test]
fn pjrt_server_batch_vs_single_consistent() {
    if !sdmm::runtime::artifacts_available("artifacts") {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    // same image submitted alone and inside a burst must yield the
    // same logits (padding must not leak across slots)
    let server = InferenceServer::start_factory(
        || CnnRunner::load("artifacts", sdmm::runtime::WeightMode::Float),
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
        },
    );
    let art = sdmm::runtime::Artifacts::load("artifacts").unwrap();
    let xs = art.f32("eval_x").unwrap();
    let img = xs[..256].to_vec();
    let solo = server.infer(img.clone()).unwrap();
    let rxs: Vec<_> = (0..16)
        .map(|i| {
            if i == 7 {
                server.submit(img.clone())
            } else {
                server.submit(xs[(i + 1) * 256..(i + 2) * 256].to_vec())
            }
        })
        .collect();
    let batched = rxs
        .into_iter()
        .enumerate()
        .map(|(_, rx)| rx.recv().unwrap().unwrap())
        .collect::<Vec<_>>();
    for (a, b) in solo.iter().zip(&batched[7]) {
        assert!((a - b).abs() < 1e-4, "solo {a} vs batched {b}");
    }
}
