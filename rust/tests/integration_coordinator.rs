//! Integration: the packing pipeline + serving coordinator — the
//! dynamic batcher/PJRT path and the sharded multi-model
//! `ServingRuntime` (bit-exactness vs the single-shard batch path,
//! scheduler fairness under saturation, exactly-once completion,
//! backpressure and shutdown-flush semantics).

use sdmm::cnn::infer::{relu, requantize, Tensor3};
use sdmm::cnn::zoo::ConvLayer;
use sdmm::coordinator::pipeline::PipelineMode;
use sdmm::coordinator::{
    AdmitError, BatchPolicy, BatchRunner, CnnRunner, InferenceServer, ModelRegistry, ModelSpec,
    PackingPipeline, ServingConfig, ServingRuntime,
};
use sdmm::packing::Layout;
use sdmm::sa::{PeArch, SaConfig, SystolicArray};
use sdmm::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

#[test]
fn packing_pipeline_end_to_end() {
    let mut rng = Rng::new(31);
    let layers: Vec<(String, Vec<f64>)> = (0..4)
        .map(|i| {
            (
                format!("layer{i}"),
                (0..3000).map(|_| rng.laplace(0.04)).collect(),
            )
        })
        .collect();
    for bits in [8u32, 6, 4] {
        let p = PackingPipeline::new(Layout::for_bits(bits).unwrap(), PipelineMode::Approximate);
        let net = p.pack_network(&layers).unwrap();
        let rep = net.report();
        assert_eq!(rep.total_weights, 12_000);
        // guaranteed WRC rates
        let expect = match bits {
            8 => 66.67,
            6 => 75.0,
            _ => 83.33,
        };
        assert!((rep.compression_percent() - expect).abs() < 0.5);
        // every layer decompresses to its effective weights
        for l in &net.layers {
            assert_eq!(net.wrom.decompress(&l.stream), l.effective_weights);
        }
        // WROM fits the paper's address space
        assert!(rep.wrom_entries as u64 <= net.wrom.paper_max_entries());
    }
}

#[test]
fn exact_mode_tunes_tuples() {
    let mut rng = Rng::new(32);
    // heavy-tailed weights: many wide-MW values force fine-tuning
    let layers = vec![(
        "w".to_string(),
        (0..3000)
            .map(|_| if rng.bool(0.5) { rng.f64() - 0.5 } else { rng.laplace(0.3) })
            .collect::<Vec<f64>>(),
    )];
    let p = PackingPipeline::new(Layout::for_bits(8).unwrap(), PipelineMode::ExactFineTuned);
    let net = p.pack_network(&layers).unwrap();
    assert!(net.exact_tuples > 0);
    assert!(
        net.tuned_tuples > 0,
        "expected some tuples to need fine-tuning"
    );
}

/// CPU-only mock runner for coordinator stress (no PJRT needed).
struct SumRunner;

impl BatchRunner for SumRunner {
    fn batch_size(&self) -> usize {
        16
    }
    fn item_len(&self) -> usize {
        8
    }
    fn out_len(&self) -> usize {
        1
    }
    fn run(&mut self, x: &[f32]) -> sdmm::error::Result<Vec<f32>> {
        Ok(x.chunks(8).map(|c| c.iter().sum()).collect())
    }
}

#[test]
fn coordinator_under_load_preserves_request_response_pairing() {
    let server = InferenceServer::start(
        SumRunner,
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_micros(300),
        },
    );
    let n = 500;
    let rxs: Vec<_> = (0..n)
        .map(|i| server.submit(vec![i as f32; 8]))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out, vec![8.0 * i as f32], "request {i} got wrong batch slot");
    }
    let m = server.shutdown();
    assert_eq!(m.requests, n as u64);
    assert!(m.latency.p99() > 0.0);
}

/// Mixed-precision model set shared by the sharded-runtime tests: one
/// 2-conv model per bit width, plus a seeded input per model.
fn mixed_set() -> Vec<(ModelSpec, Tensor3)> {
    [8u32, 6, 4]
        .iter()
        .map(|&v| {
            let layers = vec![
                ConvLayer::new("c1", 8, 4, 6, 3, 1, 1, 1),
                ConvLayer::new("c2", 8, 6, 6, 3, 1, 1, 1),
            ];
            let spec = ModelSpec::random("net", v, layers, 300 + v as u64);
            let lim = 1i64 << (v - 1);
            let mut rng = Rng::new(400 + v as u64);
            let mut input = Tensor3::zeros(4, 8, 8);
            input.data = (0..input.data.len())
                .map(|_| rng.range_i64(-lim, lim - 1))
                .collect();
            (spec, input)
        })
        .collect()
}

/// The single-shard reference: the pre-existing `run_conv_batch` path
/// (fresh packing, no registry, no sharding) with the same
/// ReLU/requantize interleaving the runtime applies.
fn reference_forward(spec: &ModelSpec, input: &Tensor3) -> Tensor3 {
    let sa =
        SystolicArray::new(SaConfig::paper_prototype(spec.v_bits, PeArch::MultiPack)).unwrap();
    let mut x = input.clone();
    for (layer, w) in spec.layers.iter().zip(&spec.weights) {
        let mut y = sa.run_conv_batch(layer, w, &x).unwrap().output.unwrap();
        relu(&mut y);
        x = requantize(&y, spec.v_bits).0;
    }
    x
}

#[test]
fn sharded_runtime_bit_exact_vs_single_shard_path() {
    let set = mixed_set();
    let registry = Arc::new(ModelRegistry::new());
    for (spec, _) in &set {
        registry.register(spec.clone()).unwrap();
    }
    for shards in [1usize, 4] {
        let rt = ServingRuntime::start(
            Arc::clone(&registry),
            ServingConfig {
                shards,
                queue_capacity: 32,
            },
        )
        .unwrap();
        for (spec, input) in &set {
            let want = reference_forward(spec, input);
            // several times so the job lands on different shards
            for _ in 0..3 {
                let got = rt.infer(&spec.key(), input.clone()).unwrap();
                assert_eq!(got.output, want, "{} on {shards} shard(s)", spec.key());
                assert_eq!(
                    got.mults,
                    spec.layers.iter().map(|l| l.macs()).sum::<u64>(),
                    "{}",
                    spec.key()
                );
            }
        }
        let snap = rt.shutdown();
        assert_eq!(snap.total_jobs(), 3 * set.len() as u64);
        assert_eq!(snap.total_failed(), 0);
    }
}

#[test]
fn sharded_runtime_fairness_and_exactly_once_under_saturation() {
    let set = mixed_set();
    let registry = Arc::new(ModelRegistry::new());
    for (spec, _) in &set {
        registry.register(spec.clone()).unwrap();
    }
    let shards = 2usize;
    let rt = ServingRuntime::start(
        Arc::clone(&registry),
        ServingConfig {
            shards,
            queue_capacity: 64,
        },
    )
    .unwrap();
    // Saturate: submit the whole burst before reading any response, so
    // admission sees real queue depths on every shard.
    let n = 48usize;
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let (spec, input) = &set[i % set.len()];
            rt.submit(&spec.key(), input.clone()).unwrap()
        })
        .collect();
    // Exactly once: every receiver yields exactly one response…
    let mut shard_hits = vec![0u64; shards];
    for rx in rxs {
        let out = rx.recv().unwrap().unwrap();
        shard_hits[out.shard] += 1;
        // …and never a second one.
        assert!(rx.recv().is_err(), "job answered twice");
    }
    let snap = rt.shutdown();
    assert_eq!(snap.total_jobs(), n as u64, "completion count != submissions");
    assert_eq!(snap.total_failed(), 0);
    // No shard starves under saturation, and the per-shard metrics
    // agree with what the responses reported.
    for (i, s) in snap.shards.iter().enumerate() {
        assert_eq!(s.jobs_ok, shard_hits[i], "shard {i} metrics drifted");
        assert!(s.jobs_ok > 0, "shard {i} starved: {shard_hits:?}");
    }
    assert!(snap.min_shard_jobs() > 0);
}

#[test]
fn sharded_runtime_backpressure_bounds_inflight() {
    let set = mixed_set();
    let registry = Arc::new(ModelRegistry::new());
    registry.register(set[0].0.clone()).unwrap();
    let key = set[0].0.key();
    let input = &set[0].1;
    let cap = 2usize;
    let rt = ServingRuntime::start(
        Arc::clone(&registry),
        ServingConfig {
            shards: 1,
            queue_capacity: cap,
        },
    )
    .unwrap();
    // Burst far past capacity without draining: the admission layer
    // must refuse with Backpressure rather than queue unboundedly.
    let mut admitted = Vec::new();
    let mut refused = 0usize;
    for _ in 0..24 {
        match rt.submit(&key, input.clone()) {
            Ok(rx) => admitted.push(rx),
            Err(AdmitError::Backpressure { queue_capacity }) => {
                assert_eq!(queue_capacity, cap);
                refused += 1;
            }
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    assert!(refused > 0, "burst of 24 into capacity 2 never backpressured");
    // Everything admitted still completes (exactly once).
    let n = admitted.len();
    for rx in admitted {
        rx.recv().unwrap().unwrap();
    }
    let snap = rt.shutdown();
    assert_eq!(snap.total_jobs(), n as u64);
    assert!(snap.shards[0].peak_depth <= cap, "in-flight exceeded the bound");
}

#[test]
fn sharded_runtime_shutdown_flushes_admitted_jobs() {
    let set = mixed_set();
    let registry = Arc::new(ModelRegistry::new());
    for (spec, _) in &set {
        registry.register(spec.clone()).unwrap();
    }
    let rt = ServingRuntime::start(
        Arc::clone(&registry),
        ServingConfig {
            shards: 2,
            queue_capacity: 32,
        },
    )
    .unwrap();
    let rxs: Vec<_> = (0..12)
        .map(|i| {
            let (spec, input) = &set[i % set.len()];
            rt.submit(&spec.key(), input.clone()).unwrap()
        })
        .collect();
    // Shut down immediately: admitted jobs must flush, not drop.
    let snap = rt.shutdown();
    assert_eq!(snap.total_jobs(), 12);
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
}

#[test]
fn sharded_runtime_drop_without_shutdown_flushes_and_joins() {
    let set = mixed_set();
    let registry = Arc::new(ModelRegistry::new());
    for (spec, _) in &set {
        registry.register(spec.clone()).unwrap();
    }
    let rt = ServingRuntime::start(
        Arc::clone(&registry),
        ServingConfig {
            shards: 2,
            queue_capacity: 32,
        },
    )
    .unwrap();
    let rxs: Vec<_> = (0..9)
        .map(|i| {
            let (spec, input) = &set[i % set.len()];
            rt.submit(&spec.key(), input.clone()).unwrap()
        })
        .collect();
    // Dropping the handle without calling shutdown() must join the
    // supervisors (no hang, no leaked threads) and still answer every
    // admitted request exactly once.
    drop(rt);
    for rx in rxs {
        rx.recv().unwrap().unwrap();
        assert!(rx.recv().is_err(), "job answered twice");
    }
}

#[test]
fn inference_server_drop_without_shutdown_flushes_and_joins() {
    let server = InferenceServer::start(
        SumRunner,
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_secs(1), // long deadline: drop must flush
        },
    );
    let rxs: Vec<_> = (0..5).map(|i| server.submit(vec![i as f32; 8])).collect();
    drop(server);
    for (i, rx) in rxs.into_iter().enumerate() {
        assert_eq!(rx.recv().unwrap().unwrap(), vec![8.0 * i as f32]);
    }
}

#[test]
fn pjrt_backed_server_roundtrip() {
    if !sdmm::runtime::artifacts_available("artifacts") {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let server = InferenceServer::start_factory(
        || CnnRunner::load("artifacts", sdmm::runtime::WeightMode::Approximated { w_bits: 8 }),
        BatchPolicy::default(),
    );
    let art = sdmm::runtime::Artifacts::load("artifacts").unwrap();
    let xs = art.f32("eval_x").unwrap();
    let logits = server.infer(xs[..256].to_vec()).unwrap();
    assert_eq!(logits.len(), 10);
    assert!(logits.iter().all(|v| v.is_finite()));
    let m = server.shutdown();
    assert_eq!(m.requests, 1);
}

#[test]
fn pjrt_server_batch_vs_single_consistent() {
    if !sdmm::runtime::artifacts_available("artifacts") {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    // same image submitted alone and inside a burst must yield the
    // same logits (padding must not leak across slots)
    let server = InferenceServer::start_factory(
        || CnnRunner::load("artifacts", sdmm::runtime::WeightMode::Float),
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
        },
    );
    let art = sdmm::runtime::Artifacts::load("artifacts").unwrap();
    let xs = art.f32("eval_x").unwrap();
    let img = xs[..256].to_vec();
    let solo = server.infer(img.clone()).unwrap();
    let rxs: Vec<_> = (0..16)
        .map(|i| {
            if i == 7 {
                server.submit(img.clone())
            } else {
                server.submit(xs[(i + 1) * 256..(i + 2) * 256].to_vec())
            }
        })
        .collect();
    let batched = rxs
        .into_iter()
        .enumerate()
        .map(|(_, rx)| rx.recv().unwrap().unwrap())
        .collect::<Vec<_>>();
    for (a, b) in solo.iter().zip(&batched[7]) {
        assert!((a - b).abs() < 1e-4, "solo {a} vs batched {b}");
    }
}
