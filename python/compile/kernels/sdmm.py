"""Layer-1 Pallas kernel: the SDMM packed-GEMM datapath.

The kernel emulates, bit-exactly and in vectorized form, what one DSP
block column of the paper's systolic array computes: for every
(batch b, weight-group mg, position k) one wide multiply

    P = A(mg,k) * Iu(b,k) + C(b,mg,k)        (DSP48E1: 25x18 mult + 48b add)

carries three independent products W_{3mg+j,k} * I_{b,k} (8-bit layout,
slot width 11). Slot extraction, the n/s shifts, the I[n-1:0] concat and
the sign stage then reconstruct the products, which accumulate over k
into out[b, m] - i.e. a full integer GEMM X @ W^T where every multiply
went through the packed datapath.

TPU adaptation (DESIGN.md par.3): the DSP's wide multiplier becomes a
wide integer vector lane; BlockSpec tiles (B_T x K) x (MG_T x K) into
VMEM the way the paper tiles IMem/WMem into BRAM. interpret=True
everywhere - CPU PJRT cannot execute Mosaic custom-calls.

Requires jax_enable_x64 (the 25x18 product + 48-bit add needs 64-bit
integer lanes).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

V_BITS = 8
SLOT_W = V_BITS + 3  # 11
A_OFFSETS = (0, 11, 22)
KW = 3
A_PORT = 25
B_PORT = 18


def _sdmm_products(a_words, x, n, s, zero, neg):
    """Vectorized packed-datapath emulation.

    a_words: [MG, K] int64 packed A words
    x:       [B, K] int64 signed activations (8-bit range)
    n,s,zero,neg: [MG, KW, K] int64 per-slot controls
    returns  [B, MG, KW, K] int64 products W_hat * I
    """
    iu = (x & 0xFF).astype(jnp.int64)  # [B, K] zero-extended input
    neg_i = (x < 0).astype(jnp.int64)  # [B, K]

    # --- C word: sign-extension compensation per slot (Eq. 7) ---
    # sex(j) = ((7 - mw_j) * neg(I)) << v | ((I >>a n_j) mod 2^v)
    mw = jnp.stack(
        [(a_words >> off) & 0x7 for off in A_OFFSETS], axis=1
    )  # [MG, KW, K]
    shifted = jnp.right_shift(x[:, None, None, :], n[None]) & 0xFF  # [B,MG,KW,K]
    sex = ((7 - mw)[None] * neg_i[:, None, None, :]) << V_BITS | shifted
    gate = 1 - zero[None]  # zero slots contribute no SEx
    sex = sex * gate
    # per-slot static offsets (python ints -> no captured constant array)
    c_word = sum(sex[:, :, j, :] << A_OFFSETS[j] for j in range(KW))  # [B,MG,K]

    # --- port sign corrections (signed 25-bit A / 18-bit B ports) ---
    a_neg = (a_words >> (A_PORT - 1)) & 1  # [MG, K]
    c_word = c_word + a_neg[None] * (iu[:, None, :] << A_PORT)
    # (B port never goes negative for v=8: Iu <= 255 << 2^17.)

    # --- the DSP op: P = A*Iu + C, wrapping mod 2^48 ---
    a_signed = a_words - (a_neg << A_PORT)  # what the signed port sees
    p = (a_signed[None] * iu[:, None, :] + c_word) & ((1 << 48) - 1)

    # --- post-processing: slot extract, sign-interpret, concat, shift ---
    slots = jnp.stack(
        [(p >> A_OFFSETS[j]) & ((1 << SLOT_W) - 1) for j in range(KW)], axis=2
    )  # [B,MG,KW,K]
    signed = slots - ((slots >> (SLOT_W - 1)) << SLOT_W)
    low_mask = (jnp.int64(1) << n) - 1  # [MG,KW,K]
    concat = (signed << n[None]) | (iu[:, None, None, :] & low_mask[None])
    prods = concat << s[None]
    prods = jnp.where(neg[None] == 1, -prods, prods)
    prods = jnp.where(zero[None] == 1, 0, prods)
    return prods


def _kernel(x_ref, a_ref, n_ref, s_ref, zero_ref, neg_ref, o_ref):
    x = x_ref[...].astype(jnp.int64)
    a = a_ref[...].astype(jnp.int64)
    n = n_ref[...].astype(jnp.int64)
    s = s_ref[...].astype(jnp.int64)
    z = zero_ref[...].astype(jnp.int64)
    ng = neg_ref[...].astype(jnp.int64)
    prods = _sdmm_products(a, x, n, s, z, ng)  # [B, MG, KW, K]
    # Accumulate over K (the LUT adder tree of the PE) and unfold the
    # (MG, KW) axes into M = 3*MG output channels.
    acc = jnp.sum(prods, axis=-1)  # [B, MG, KW]
    b, mg, kw = acc.shape
    o_ref[...] = acc.reshape(b, mg * kw).astype(jnp.int32)


def sdmm_gemm(x, a_words, n, s, zero, neg, *, block_b: int = 0, block_mg: int = 0):
    """Packed-datapath GEMM: out[b, m] = sum_k W_hat[m, k] * x[b, k].

    x: [B, K] int32; a_words: [MG, K] int32/int64;
    n, s, zero, neg: [MG, KW, K] int32.
    Returns [B, 3*MG] int32.

    block_b / block_mg tile the batch / weight-group axes through VMEM
    (0 = whole axis in one block).
    """
    b, k = x.shape
    mg = a_words.shape[0]
    bb = block_b or b
    bmg = block_mg or mg
    assert b % bb == 0 and mg % bmg == 0
    grid = (b // bb, mg // bmg)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bmg, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bmg, KW, k), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bmg, KW, k), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bmg, KW, k), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bmg, KW, k), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bmg * KW), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, mg * KW), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, a_words, n, s, zero, neg)


def pack_controls(packed: dict):
    """Reshape pack_weight_matrix outputs ([M, K]) into the kernel's
    [MG, KW, K] control layout."""
    import numpy as np

    m, k = packed["n"].shape
    mg = m // KW

    def rs(key):
        return np.ascontiguousarray(packed[key].reshape(mg, KW, k)).astype(np.int32)

    return dict(
        a_words=packed["a_words"].astype(np.int32),
        n=rs("n"),
        s=rs("s"),
        zero=rs("zero"),
        neg=rs("neg"),
    )
