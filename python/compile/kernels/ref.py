"""Pure-jnp correctness oracle for the SDMM kernel.

The packed datapath must equal an ordinary integer GEMM against the
*approximated* weights - exactly, not allclose: every value is an
integer identity. `ref_gemm` is that GEMM; `python/tests/test_kernel.py`
asserts bitwise equality against `sdmm.sdmm_gemm` across shapes, seeds
and weight distributions (hypothesis).
"""

import jax.numpy as jnp


def ref_gemm(x, w_approx):
    """out[b, m] = sum_k w_approx[m, k] * x[b, k] in int64, cast int32.

    x: [B, K] int; w_approx: [M, K] int (already Eq.4-approximated).
    """
    out = jnp.einsum(
        "bk,mk->bm", x.astype(jnp.int64), w_approx.astype(jnp.int64)
    )
    return out.astype(jnp.int32)


def ref_gemm_numpy(x, w_approx):
    """NumPy twin used by the aot manifest self-check."""
    import numpy as np

    return np.einsum(
        "bk,mk->bm", x.astype(np.int64), w_approx.astype(np.int64)
    ).astype(np.int32)
