# Build-time compile path (Layer 1 + Layer 2). Never imported at runtime:
# the Rust binary only consumes the artifacts this package emits.
