"""AOT export: train the Layer-2 model, lower everything to HLO text,
dump weights + eval data for the Rust runtime.

Artifacts (all consumed by rust/src/runtime):
  cnn_fwd.hlo.txt    - forward pass, params as runtime arguments
  sdmm_gemm.hlo.txt  - the Layer-1 Pallas packed-GEMM kernel (interpret
                       lowering -> plain HLO, runnable on CPU PJRT)
  weights.bin        - trained f32 weights + eval set (custom binary)
  manifest.json      - tensor table + metadata

HLO *text* is the interchange format, NOT serialized protos: jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import struct
import sys

import jax

jax.config.update("jax_enable_x64", True)  # the kernel needs int64 lanes

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref, sdmm
from . import sdmm_lib

# Fixed shapes baked into the artifacts (mirrored in rust/src/runtime).
SERVE_BATCH = 16
GEMM_B, GEMM_K, GEMM_MG = 8, 64, 16  # M = 48


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_cnn_fwd():
    shapes = [s for _, s in M.param_shapes()]
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    x_spec = jax.ShapeDtypeStruct((SERVE_BATCH, 1, M.INPUT_HW, M.INPUT_HW), jnp.float32)

    def fwd(*args):
        params = list(args[:-1])
        return (M.forward(params, args[-1]),)

    lowered = jax.jit(fwd).lower(*specs, x_spec)
    return to_hlo_text(lowered)


def lower_sdmm_gemm():
    i32 = jnp.int32
    specs = [
        jax.ShapeDtypeStruct((GEMM_B, GEMM_K), i32),            # x
        jax.ShapeDtypeStruct((GEMM_MG, GEMM_K), i32),           # a_words
        jax.ShapeDtypeStruct((GEMM_MG, 3, GEMM_K), i32),        # n
        jax.ShapeDtypeStruct((GEMM_MG, 3, GEMM_K), i32),        # s
        jax.ShapeDtypeStruct((GEMM_MG, 3, GEMM_K), i32),        # zero
        jax.ShapeDtypeStruct((GEMM_MG, 3, GEMM_K), i32),        # neg
    ]

    def fn(x, a, n, s, z, ng):
        return (sdmm.sdmm_gemm(x, a, n, s, z, ng),)

    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def kernel_self_check():
    """Build-time gate: the Pallas kernel must equal the oracle exactly
    on a random packed problem before we ship artifacts."""
    rng = np.random.default_rng(0)
    wq = rng.integers(-128, 128, size=(GEMM_MG * 3, GEMM_K))
    x = rng.integers(-128, 128, size=(GEMM_B, GEMM_K)).astype(np.int32)
    packed = sdmm_lib.pack_weight_matrix(wq, 8)
    ctl = sdmm.pack_controls(packed)
    out = sdmm.sdmm_gemm(
        jnp.asarray(x),
        jnp.asarray(ctl["a_words"]),
        jnp.asarray(ctl["n"]),
        jnp.asarray(ctl["s"]),
        jnp.asarray(ctl["zero"]),
        jnp.asarray(ctl["neg"]),
    )
    want = ref.ref_gemm_numpy(x, packed["w_approx"])
    if not np.array_equal(np.asarray(out), want):
        raise SystemExit("sdmm kernel self-check FAILED (kernel != oracle)")
    return ctl, x, want


class BinWriter:
    """weights.bin: concatenated little-endian tensors + manifest table."""

    def __init__(self, path):
        self.f = open(path, "wb")
        self.table = []
        self.offset = 0

    def add(self, name, arr):
        arr = np.ascontiguousarray(arr)
        dtype = {"float32": "f32", "int32": "i32"}[str(arr.dtype)]
        raw = arr.tobytes()
        self.f.write(raw)
        self.table.append(
            dict(name=name, dtype=dtype, shape=list(arr.shape), offset=self.offset,
                 bytes=len(raw))
        )
        self.offset += len(raw)

    def close(self):
        self.f.close()
        return self.table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    print("[aot] kernel self-check (pallas vs oracle)...", flush=True)
    ctl, x_k, want_k = kernel_self_check()
    print("[aot] kernel self-check OK")

    print("[aot] training tiny CNN...", flush=True)
    params, (x_ev, y_ev), acc = M.train(seed=args.seed, steps=args.steps)
    print(f"[aot] train done, eval accuracy = {acc:.3f}")
    if acc < 0.8:
        raise SystemExit(f"training failed to converge (acc={acc})")

    print("[aot] lowering cnn_fwd...", flush=True)
    with open(os.path.join(out, "cnn_fwd.hlo.txt"), "w") as f:
        f.write(lower_cnn_fwd())
    print("[aot] lowering sdmm_gemm (pallas, interpret)...", flush=True)
    with open(os.path.join(out, "sdmm_gemm.hlo.txt"), "w") as f:
        f.write(lower_sdmm_gemm())

    print("[aot] writing weights.bin + manifest.json...", flush=True)
    w = BinWriter(os.path.join(out, "weights.bin"))
    for (name, _), p in zip(M.param_shapes(), params):
        w.add(name, np.asarray(p, dtype=np.float32))
    w.add("eval_x", np.asarray(x_ev, dtype=np.float32))
    w.add("eval_y", np.asarray(y_ev, dtype=np.int32))
    # the kernel-artifact regression vectors (rust runtime test)
    w.add("gemm_x", x_k.astype(np.int32))
    for key in ("a_words", "n", "s", "zero", "neg"):
        w.add(f"gemm_{key}", ctl[key].astype(np.int32))
    w.add("gemm_out", want_k.astype(np.int32))
    table = w.close()

    manifest = dict(
        hlo=dict(cnn_fwd="cnn_fwd.hlo.txt", sdmm_gemm="sdmm_gemm.hlo.txt"),
        serve_batch=SERVE_BATCH,
        input_hw=M.INPUT_HW,
        num_classes=M.NUM_CLASSES,
        gemm=dict(b=GEMM_B, k=GEMM_K, mg=GEMM_MG),
        train_accuracy=acc,
        weights="weights.bin",
        tensors=table,
    )
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done -> {out}")


if __name__ == "__main__":
    main()
