"""Layer-2 JAX model: the small CNN served by the Rust coordinator.

Architecture (mirrored by rust/src/cnn/zoo.rs::tiny_cnn):

    input [B, 1, 16, 16]
      conv 3x3 pad 1 ->  8 ch, relu, maxpool2   -> [B,  8, 8, 8]
      conv 3x3 pad 1 -> 16 ch, relu, maxpool2   -> [B, 16, 4, 4]
      conv 3x3 pad 1 -> 32 ch, relu, maxpool2   -> [B, 32, 2, 2]
      fc 128 -> 10 logits

Weights enter as *parameters* of the lowered HLO so the Rust runtime can
feed either plain-quantized or SDMM-approximated weights into the same
executable and measure the Table 2 delta end-to-end.

The forward pass is pure f32 compute over dequantized weights: the
integer identity (SDMM == approx-weight multiply) is established at the
kernel level (test_kernel.py) and by the Rust DSP model; the serving
graph then uses the mathematically-equal dense form (DESIGN.md par.4).
"""

import jax
import jax.numpy as jnp

CONVS = ((1, 8), (8, 16), (16, 32))
FC = (128, 10)
INPUT_HW = 16
NUM_CLASSES = 10


def param_shapes():
    """Ordered (name, shape) of all parameters."""
    shapes = []
    for i, (cin, cout) in enumerate(CONVS):
        shapes.append((f"conv{i + 1}_w", (cout, cin, 3, 3)))
    shapes.append(("fc_w", (FC[1], FC[0])))
    return shapes


def init_params(key):
    params = []
    for name, shape in param_shapes():
        key, sub = jax.random.split(key)
        fan_in = 1
        for d in shape[1:]:
            fan_in *= d
        params.append(jax.random.normal(sub, shape) * (2.0 / fan_in) ** 0.5)
    return params


def forward(params, x):
    """x: [B, 1, 16, 16] f32 -> logits [B, 10] f32."""
    h = x
    for w in params[:-1]:
        h = jax.lax.conv_general_dilated(
            h, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(
            h, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
        )
    b = h.shape[0]
    h = h.reshape(b, -1)
    return h @ params[-1].T


def loss_fn(params, x, y):
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def make_prototypes(key):
    """Class prototypes: low-pass-filtered random patterns (shared
    between the train and eval splits)."""
    protos = jax.random.normal(key, (NUM_CLASSES, 1, INPUT_HW, INPUT_HW))
    kernel = jnp.ones((1, 1, 3, 3)) / 9.0
    for _ in range(2):
        protos = jax.lax.conv_general_dilated(
            protos, kernel, (1, 1), "SAME",
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )
    return protos


def make_dataset(key, n, protos=None):
    """Synthetic 10-class task: prototype + Gaussian noise. Linearly
    separable enough to train in seconds, hard enough that quantization
    error is visible in the logit margins. If `protos` is None the key
    is split to derive them (single-split convenience)."""
    kp, k2, k3 = jax.random.split(key, 3)
    if protos is None:
        protos = make_prototypes(kp)
    labels = jax.random.randint(k2, (n,), 0, NUM_CLASSES)
    noise = jax.random.normal(k3, (n, 1, INPUT_HW, INPUT_HW)) * 0.7
    images = protos[labels] + noise
    return images, labels


def train(seed: int = 0, steps: int = 400, batch: int = 64, lr: float = 3e-2):
    """Train with plain SGD + momentum (no external deps). Returns
    (params, final train accuracy on a held-out batch)."""
    key = jax.random.PRNGKey(seed)
    kp, kproto, kd, ke = jax.random.split(key, 4)
    params = init_params(kp)
    protos = make_prototypes(kproto)
    x_all, y_all = make_dataset(kd, 4096, protos)
    x_ev, y_ev = make_dataset(ke, 1024, protos)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    mom = [jnp.zeros_like(p) for p in params]

    import numpy as np

    rng = np.random.default_rng(seed)
    for step in range(steps):
        idx = rng.integers(0, x_all.shape[0], size=batch)
        _, grads = grad_fn(params, x_all[idx], y_all[idx])
        mom = [0.9 * m + g for m, g in zip(mom, grads)]
        params = [p - lr * m for p, m in zip(params, mom)]

    logits = jax.jit(forward)(params, x_ev)
    acc = float(jnp.mean(jnp.argmax(logits, axis=1) == y_ev))
    return params, (x_ev, y_ev), acc
