"""Python mirror of the Rust packing pipeline (rust/src/manip, packing).

Only what the Layer-1/2 build path needs: Algorithm 1, the Eq. 4
approximation, and the 8-bit (3 weights x 1 input) A-word packing used
by the Pallas GEMM kernel. Kept deliberately small - the Rust crate is
the source of truth; `python/tests/test_crosscheck.py` pins the two
implementations against the same vectors.
"""

from functools import lru_cache

import numpy as np

APPROX_MW = (0, 1, 3, 5, 7)
# 8-bit layout constants (DESIGN.md par.3): slot width v+3, offsets 0/11/22.
V_BITS = 8
SLOT_W = V_BITS + 3
A_OFFSETS = (0, 11, 22)
KW = 3


def manipulate(w: int):
    """Algorithm 1: w = 2^s * (1 + 2^n * mw), minimal mw. w > 0."""
    assert w > 0
    s = 0
    while w % 2 == 0:
        s += 1
        w //= 2
    w -= 1
    n = 0
    if w > 0:
        while w % 2 == 0:
            n += 1
            w //= 2
    return w, n, s  # mw, n, s


@lru_cache(maxsize=None)
def representable(max_mag: int):
    """Sorted magnitudes 2^s(1+2^n*mw) <= max_mag, mw in APPROX_MW."""
    vals = set()
    for mw in APPROX_MW:
        for n in range(max_mag.bit_length() + 1):
            base = 1 + (mw << n)
            if base > max_mag:
                break
            v = base
            while v <= max_mag:
                vals.add(v)
                v *= 2
    return tuple(sorted(vals))


@lru_cache(maxsize=None)
def approx_table(c_bits: int):
    """magnitude -> nearest representable (ties toward smaller)."""
    max_mag = 1 << (c_bits - 1)
    reps = representable(max_mag)
    table = {}
    arr = np.asarray(reps)
    for m in range(1, max_mag + 1):
        i = int(np.searchsorted(arr, m))
        lo = arr[i - 1] if i > 0 else None
        hi = arr[i] if i < len(arr) else None
        if lo is None:
            best = hi
        elif hi is None:
            best = lo
        else:
            best = lo if m - lo <= hi - m else hi
        table[m] = int(best)
    return table


def approximate_signed(value: int, c_bits: int):
    """-> (zero, negative, mw, n, s, magnitude) after Eq. 4."""
    if value == 0:
        return True, False, 0, 0, 0, 0
    neg = value < 0
    max_mag = 1 << (c_bits - 1)
    mag = min(abs(value), max_mag)
    mag = approx_table(c_bits)[mag]
    mw, n, s = manipulate(mag)
    assert mw in APPROX_MW
    return False, neg, mw, n, s, mag


def pack_weight_matrix(wq: np.ndarray, c_bits: int = 8):
    """Pack an [M, K] int weight matrix along M in groups of 3 (the
    weight-stationary SDMM arrangement: three output channels share one
    input). M must be a multiple of 3.

    Returns dict of arrays:
      a_words [M/3, K] int64, and per-weight controls [M, K] int32:
      n, s, zero, neg, plus approximated signed weights w_approx [M, K].
    """
    m, k = wq.shape
    assert m % KW == 0, f"M={m} not a multiple of {KW}"
    a_words = np.zeros((m // KW, k), dtype=np.int64)
    n_arr = np.zeros((m, k), dtype=np.int32)
    s_arr = np.zeros((m, k), dtype=np.int32)
    zero = np.zeros((m, k), dtype=np.int32)
    neg = np.zeros((m, k), dtype=np.int32)
    w_approx = np.zeros((m, k), dtype=np.int64)
    for kk in range(k):
        for mg in range(m // KW):
            a = 0
            for j in range(KW):
                mm = mg * KW + j
                z, ng, mw, n, s, mag = approximate_signed(int(wq[mm, kk]), c_bits)
                a |= mw << A_OFFSETS[j]
                n_arr[mm, kk] = n
                s_arr[mm, kk] = s
                zero[mm, kk] = int(z)
                neg[mm, kk] = int(ng)
                w_approx[mm, kk] = 0 if z else (-mag if ng else mag)
            a_words[mg, kk] = a
    return dict(a_words=a_words, n=n_arr, s=s_arr, zero=zero, neg=neg, w_approx=w_approx)


def approximate_array(wq: np.ndarray, c_bits: int) -> np.ndarray:
    """Elementwise Eq. 4 approximation of a signed integer array."""
    out = np.zeros_like(wq, dtype=np.int64)
    flat_in = wq.reshape(-1)
    flat_out = out.reshape(-1)
    for i, v in enumerate(flat_in):
        z, ng, _, _, _, mag = approximate_signed(int(v), c_bits)
        flat_out[i] = 0 if z else (-mag if ng else mag)
    return out
