"""L1 correctness: the Pallas SDMM kernel vs the pure-jnp oracle.

Equality is EXACT (integer identity), never allclose. hypothesis sweeps
shapes, seeds and weight distributions.
"""

import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import sdmm_lib
from compile.kernels import ref, sdmm


def run_pair(wq: np.ndarray, x: np.ndarray, block_b=0, block_mg=0):
    packed = sdmm_lib.pack_weight_matrix(wq, 8)
    ctl = sdmm.pack_controls(packed)
    out = sdmm.sdmm_gemm(
        jnp.asarray(x.astype(np.int32)),
        jnp.asarray(ctl["a_words"]),
        jnp.asarray(ctl["n"]),
        jnp.asarray(ctl["s"]),
        jnp.asarray(ctl["zero"]),
        jnp.asarray(ctl["neg"]),
        block_b=block_b,
        block_mg=block_mg,
    )
    want = ref.ref_gemm_numpy(x, packed["w_approx"])
    return np.asarray(out), want


def test_small_known():
    # W rows: [3, -44, 0]; x column of ones -> out = row sums of W_hat.
    wq = np.array([[3, 3], [-44, -44], [0, 0]])
    x = np.ones((1, 2), dtype=np.int32)
    out, want = run_pair(wq, x)
    assert out.tolist() == [[6, -88, 0]]
    assert np.array_equal(out, want)


def test_extremes():
    wq = np.array([[-128, 127, -1], [127, -128, 1], [15, -15, 0]])
    x = np.array([[-128, 127, -1], [0, 1, -8]], dtype=np.int32)
    out, want = run_pair(wq, x)
    assert np.array_equal(out, want)


def test_random_dense():
    rng = np.random.default_rng(1)
    wq = rng.integers(-128, 128, size=(12, 32))
    x = rng.integers(-128, 128, size=(4, 32)).astype(np.int32)
    out, want = run_pair(wq, x)
    assert np.array_equal(out, want)


def test_blocked_grid_matches_single_block():
    rng = np.random.default_rng(2)
    wq = rng.integers(-128, 128, size=(24, 16))
    x = rng.integers(-128, 128, size=(8, 16)).astype(np.int32)
    a, want = run_pair(wq, x)
    b, _ = run_pair(wq, x, block_b=4, block_mg=2)
    assert np.array_equal(a, want)
    assert np.array_equal(b, want)


def test_zero_weights_and_inputs():
    wq = np.zeros((6, 8), dtype=np.int64)
    x = np.zeros((2, 8), dtype=np.int32)
    out, want = run_pair(wq, x)
    assert out.sum() == 0
    assert np.array_equal(out, want)


def test_laplacian_network_like():
    rng = np.random.default_rng(3)
    wq = np.clip(np.round(rng.laplace(0, 5.0, size=(48, 64))), -128, 127).astype(int)
    x = np.clip(np.round(rng.laplace(0, 20.0, size=(8, 64))), -128, 127).astype(np.int32)
    out, want = run_pair(wq, x)
    assert np.array_equal(out, want)


@settings(max_examples=40, deadline=None)
@given(
    mg=st.integers(1, 6),
    k=st.integers(1, 24),
    b=st.integers(1, 5),
    seed=st.integers(0, 2**31),
    scale=st.sampled_from([1.0, 4.0, 30.0, 128.0]),
)
def test_hypothesis_sweep(mg, k, b, seed, scale):
    rng = np.random.default_rng(seed)
    wq = np.clip(np.round(rng.laplace(0, scale, size=(3 * mg, k))), -128, 127).astype(int)
    x = rng.integers(-128, 128, size=(b, k)).astype(np.int32)
    out, want = run_pair(wq, x)
    assert np.array_equal(out, want)


def test_manipulation_identity():
    for w in range(1, 129):
        mw, n, s = sdmm_lib.manipulate(w)
        assert (1 + (mw << n)) << s == w


def test_representable_counts_match_rust():
    # pinned against rust/src/manip tests
    assert len(sdmm_lib.representable(128)) == 64
    assert len(sdmm_lib.representable(32)) == 28
    assert len(sdmm_lib.representable(8)) == 8


@given(st.integers(-128, 127))
@settings(max_examples=256, deadline=None)
def test_approximation_sound(v):
    z, neg, mw, n, s, mag = sdmm_lib.approximate_signed(v, 8)
    if z:
        assert v == 0
    else:
        assert mw in sdmm_lib.APPROX_MW
        assert (1 + (mw << n)) << s == mag
        assert abs(mag - min(abs(v), 128)) <= 4
        assert neg == (v < 0)
