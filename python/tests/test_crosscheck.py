"""Cross-implementation pinning: the Python build-path mirror
(compile/sdmm_lib.py) must agree with the Rust crate on shared vectors.

The Rust side pins the same vectors in rust/src/manip tests; if either
implementation drifts, one of the two suites breaks.
"""

import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import sdmm_lib

# (input, mw, n, s) — Algorithm 1 vectors (match rust manip tests)
MANIP_VECTORS = [
    (44, 5, 1, 2),     # paper Fig. 2: 44 = 2^2 (1 + 2^1 * 5)
    (1, 0, 0, 0),
    (128, 0, 0, 7),
    (3, 1, 1, 0),
    (7, 3, 1, 0),
    (15, 7, 1, 0),
    (22, 5, 1, 1),
    (96, 1, 1, 5),
    (127, 63, 1, 0),
]

# (signed value, approx magnitude) — Eq. 4 vectors
APPROX_VECTORS = [
    (23, 22),     # nearest representable, tie-break low (rust-pinned)
    (-23, 22),
    (44, 44),     # exact
    (127, 128),   # rounds up to the power of two
    (-128, 128),
    (89, 88),
    (11, 11),
    (54, 52),
]


def test_manipulation_vectors():
    for w, mw, n, s in MANIP_VECTORS:
        assert sdmm_lib.manipulate(w) == (mw, n, s), f"w={w}"


def test_approximation_vectors():
    for v, mag in APPROX_VECTORS:
        z, neg, mw, n, s, m = sdmm_lib.approximate_signed(v, 8)
        assert not z
        assert m == mag, f"v={v}: {m} != {mag}"
        assert neg == (v < 0)


def test_representable_set_sizes_match_rust():
    assert len(sdmm_lib.representable(128)) == 64
    assert len(sdmm_lib.representable(32)) == 28
    assert len(sdmm_lib.representable(8)) == 8


def test_exactly_128_of_256():
    exact = 0
    for v in range(-128, 128):
        if v == 0:
            exact += 1
            continue
        z, _, _, _, _, mag = sdmm_lib.approximate_signed(v, 8)
        if mag == min(abs(v), 128):
            exact += 1
    assert exact == 128


def test_a_word_layout_matches_rust():
    # rust: pack_approx(&l8, &[-44, 3, 96]) -> slots mw 5,1,1 at 0/11/22
    import numpy as np

    packed = sdmm_lib.pack_weight_matrix(np.array([[-44], [3], [96]]), 8)
    a = int(packed["a_words"][0, 0])
    assert a & 0x7 == 5           # |−44| -> MW 5
    assert (a >> 11) & 0x7 == 1   # 3 -> MW 1
    assert (a >> 22) & 0x7 == 1   # 96 -> MW 1
    assert packed["w_approx"][:, 0].tolist() == [-44, 3, 96]
