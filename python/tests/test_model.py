"""L2 model checks: shapes, training smoke, parameter-count parity with
the Rust zoo (rust/src/cnn/zoo.rs::tiny_cnn)."""

import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile import model as M


def test_param_shapes():
    shapes = dict(M.param_shapes())
    assert shapes["conv1_w"] == (8, 1, 3, 3)
    assert shapes["conv2_w"] == (16, 8, 3, 3)
    assert shapes["conv3_w"] == (32, 16, 3, 3)
    assert shapes["fc_w"] == (10, 128)


def test_param_count_matches_rust_zoo():
    # rust tiny_cnn: conv params 8*9 + 16*8*9 + 32*16*9 = 5832; fc 1280.
    total = 0
    for _, s in M.param_shapes():
        n = 1
        for d in s:
            n *= d
        total += n
    assert total == 5832 + 1280


def test_forward_shape_and_finite():
    key = jax.random.PRNGKey(0)
    params = M.init_params(key)
    x = jax.random.normal(key, (4, 1, 16, 16))
    logits = M.forward(params, x)
    assert logits.shape == (4, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_dataset_balanced_and_deterministic():
    x1, y1 = M.make_dataset(jax.random.PRNGKey(7), 500)
    x2, y2 = M.make_dataset(jax.random.PRNGKey(7), 500)
    assert np.array_equal(np.asarray(y1), np.asarray(y2))
    assert np.array_equal(np.asarray(x1), np.asarray(x2))
    # all classes present
    assert len(set(np.asarray(y1).tolist())) == M.NUM_CLASSES


def test_training_converges_fast_smoke():
    # short run: must beat chance by a wide margin
    _, _, acc = M.train(seed=1, steps=120, batch=64)
    assert acc > 0.5, f"accuracy {acc}"
