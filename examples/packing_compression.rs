//! Compression scenario (paper §5 / Table 3): run the weight-packing
//! compiler on a distribution-matched AlexNet, show the WROM build,
//! the WRC index stream, and the composed Huffman/pruning pipelines.
//!
//! Run: `cargo run --release --example packing_compression`

use sdmm::cnn::weights::synth_layer_weights;
use sdmm::cnn::zoo::{Model, ModelKind};
use sdmm::compress::wrc_compress;
use sdmm::coordinator::{PackingPipeline, PackingReport};
use sdmm::coordinator::pipeline::PipelineMode;
use sdmm::packing::Layout;
use sdmm::util::rng::Rng;

fn main() -> sdmm::error::Result<()> {
    let model = Model::build(ModelKind::Alexnet);
    let mut rng = Rng::new(7);
    // per-layer float weights (subsampled so the demo runs in seconds)
    let layers: Vec<(String, Vec<f64>)> = model
        .convs
        .iter()
        .map(|l| {
            let w = synth_layer_weights(l, &mut rng);
            let stride = (w.len() / 120_000).max(1);
            (
                l.name.to_string(),
                w.into_iter().step_by(stride).collect(),
            )
        })
        .collect();
    let total: usize = layers.iter().map(|(_, w)| w.len()).sum();
    println!("packing {} AlexNet conv weights (subsampled)", total);

    for bits in [8u32, 6, 4] {
        let layout = Layout::for_bits(bits)?;
        let pipeline = PackingPipeline::new(layout.clone(), PipelineMode::Approximate);
        let net = pipeline.pack_network(&layers)?;
        let rep: PackingReport = net.report();
        println!(
            "\n{bits}-bit: WROM {} entries ({:.1} KB), index {} bits/group, \
             off-chip {:.2}% of original (paper WRC: {:.1}%)",
            rep.wrom_entries,
            rep.wrom_bits as f64 / 8192.0,
            rep.index_bits_per_group,
            rep.compression_percent(),
            match bits {
                8 => 66.6,
                6 => 75.0,
                _ => 83.3,
            },
        );

        // the composed Table 3 pipelines on the same stream
        let ws: Vec<i64> = net
            .layers
            .iter()
            .flat_map(|l| l.effective_weights.iter().copied())
            .collect();
        let r = wrc_compress(&layout, &ws, 0.65)?;
        println!(
            "  H {:.2}%   WRC+H {:.2}%   P+WRC+H {:.2}%",
            r.huffman_only.percent(),
            r.wrc_huffman.percent(),
            r.prune_wrc_huffman.percent()
        );
    }

    // round-trip sanity: decompress == effective weights
    let layout = Layout::for_bits(8)?;
    let pipeline = PackingPipeline::new(layout, PipelineMode::Approximate);
    let net = pipeline.pack_network(&layers)?;
    for l in &net.layers {
        assert_eq!(net.wrom.decompress(&l.stream), l.effective_weights);
    }
    println!("\nround-trip (index stream -> weights) verified; packing_compression OK");
    Ok(())
}
