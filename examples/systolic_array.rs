//! Systolic-array scenario: run a real AlexNet conv layer (scaled
//! input) through the bit-accurate MP array simulator, verify against
//! the golden integer convolution, and print the Table 4/5-style
//! resource + cycle summary for all three PE architectures.
//!
//! Run: `cargo run --release --example systolic_array`

use sdmm::cnn::infer::{approximate_weights, conv2d_int, Tensor3};
use sdmm::cnn::zoo::ConvLayer;
use sdmm::resources::area::array_area;
use sdmm::sa::{PeArch, SaConfig, SystolicArray};
use sdmm::util::rng::Rng;

fn main() -> sdmm::error::Result<()> {
    // AlexNet conv3 geometry, spatially scaled (13->9) so the
    // bit-accurate run finishes in seconds.
    let layer = ConvLayer::new("conv3-mini", 9, 32, 48, 3, 1, 1, 1);
    let mut rng = Rng::new(2024);
    let weights: Vec<i64> = (0..layer.params())
        .map(|_| (rng.laplace(6.0)).round().clamp(-128.0, 127.0) as i64)
        .collect();
    let mut input = Tensor3::zeros(layer.in_ch, layer.in_hw, layer.in_hw);
    input.data = (0..input.data.len()).map(|_| rng.range_i64(-128, 127)).collect();

    println!("layer {}: {} MACs", layer.name, layer.macs());

    // --- bit-accurate MP run, golden-checked -------------------------
    let cfg = SaConfig::paper_prototype(8, PeArch::MultiPack);
    let sa = SystolicArray::new(cfg.clone())?;
    let run = sa.run_conv(&layer, &weights, &input)?;
    let golden = conv2d_int(&input, &approximate_weights(&weights, 8), &layer);
    assert_eq!(run.output.as_ref().unwrap(), &golden, "bit-accurate mismatch!");
    println!(
        "MP  : {} DSP ops for {} multiplications ({:.2} mult/DSP-op) — output golden-checked",
        run.dsp_ops,
        run.mults,
        run.mults as f64 / run.dsp_ops as f64
    );

    // --- cycle + resource summary across architectures ---------------
    println!(
        "\n{:<5} {:>6} {:>9} {:>10} {:>10} {:>8} {:>9} {:>10}",
        "arch", "DSP", "LUT", "DFF", "cycles", "util", "time(us)", "W-bits"
    );
    for arch in [PeArch::OneMac, PeArch::TwoMult, PeArch::MultiPack] {
        let cfg = SaConfig::paper_prototype(8, arch);
        let sa = SystolicArray::new(cfg.clone())?;
        let est = sa.estimate_layer(&layer);
        let area = array_area(&cfg);
        println!(
            "{:<5} {:>6} {:>9} {:>10} {:>10} {:>7.1}% {:>9.1} {:>10}",
            arch.name(),
            area.dsp,
            area.lut_total(),
            area.dff,
            est.cycles,
            est.utilization(&cfg) * 100.0,
            est.time_us(&cfg),
            est.traffic.offchip_weight_bits,
        );
    }
    println!(
        "\npaper headline: MP cuts DSP usage by 66.6% (8-bit), 75% (6-bit), 83.3% (4-bit)"
    );
    for v in [8u32, 6, 4] {
        let m1 = array_area(&SaConfig::paper_prototype(v, PeArch::OneMac));
        let mp = array_area(&SaConfig::paper_prototype(v, PeArch::MultiPack));
        println!(
            "  {v}-bit: {} -> {} DSPs ({:.1}% fewer)",
            m1.dsp,
            mp.dsp,
            (1.0 - mp.dsp as f64 / m1.dsp as f64) * 100.0
        );
    }
    println!("systolic_array OK");
    Ok(())
}
