//! End-to-end driver (DESIGN.md §5, "§6 e2e" row): load the trained
//! CNN artifacts, serve batched inference requests through the Rust
//! coordinator + PJRT runtime with SDMM-approximated weights, and
//! report accuracy (quantized vs approximated) plus serving
//! latency/throughput.
//!
//! This is the serving-paper driver the system prompt requires: a real
//! (small) model, batched requests, latency/throughput reported, with
//! the paper's technique (weight approximation + packing) in the loop.
//!
//! Run after `make artifacts`:
//!   `cargo run --release --example serve_cnn`

use sdmm::coordinator::{BatchPolicy, CnnRunner, InferenceServer};
use sdmm::runtime::{Artifacts, WeightMode};
use std::time::Instant;

fn main() -> sdmm::error::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    if !sdmm::runtime::artifacts_available(&dir) {
        sdmm::bail!("artifacts missing — run `make artifacts` first");
    }
    let art = Artifacts::load(&dir)?;
    let xs = art.f32("eval_x")?;
    let ys = art.i32("eval_y")?;
    let item = 16 * 16;
    let n_eval = ys.len().min(512);

    println!("== accuracy: quantized vs SDMM-approximated (Table 2 e2e) ==");
    for w_bits in [8u32, 6, 4] {
        let mut errs = Vec::new();
        for mode in [
            WeightMode::Quantized { w_bits },
            WeightMode::Approximated { w_bits },
        ] {
            let dir2 = dir.clone();
            let server = InferenceServer::start_factory(
                move || CnnRunner::load(&dir2, mode),
                BatchPolicy::default(),
            );
            let mut wrong = 0usize;
            let rxs: Vec<_> = (0..n_eval)
                .map(|i| server.submit(xs[i * item..(i + 1) * item].to_vec()))
                .collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let logits = rx.recv()??;
                let pred = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred as i32 != ys[i] {
                    wrong += 1;
                }
            }
            server.shutdown();
            errs.push(wrong as f64 / n_eval as f64 * 100.0);
        }
        println!(
            "W={w_bits}b: err(quant) {:>5.2}%  err(approx) {:>5.2}%  delta {:+.2} pp{}",
            errs[0],
            errs[1],
            errs[1] - errs[0],
            if w_bits == 4 { "  (must be +0.00: 4-bit exact)" } else { "" }
        );
        if w_bits == 4 {
            assert_eq!(errs[0], errs[1], "4-bit approximation must be lossless");
        }
    }

    println!("\n== serving: batched throughput/latency (approx 8-bit) ==");
    let dir2 = dir.clone();
    let server = InferenceServer::start_factory(
        move || CnnRunner::load(&dir2, WeightMode::Approximated { w_bits: 8 }),
        BatchPolicy::default(),
    );
    let requests = 2048usize;
    let concurrency = 64usize;
    let t0 = Instant::now();
    let mut inflight = std::collections::VecDeque::new();
    let (mut sent, mut done) = (0usize, 0usize);
    while done < requests {
        while inflight.len() < concurrency && sent < requests {
            let off = (sent * item) % (xs.len() - item);
            inflight.push_back(server.submit(xs[off..off + item].to_vec()));
            sent += 1;
        }
        if let Some(rx) = inflight.pop_front() {
            rx.recv()??;
            done += 1;
        }
    }
    let wall = t0.elapsed();
    let m = server.shutdown();
    println!(
        "{} requests in {:.3}s -> {:.0} req/s | latency p50 {:.2}ms p99 {:.2}ms | \
         {} batches, occupancy {:.1}%",
        m.requests,
        wall.as_secs_f64(),
        m.throughput_per_sec(wall),
        m.latency.p50() / 1e6,
        m.latency.p99() / 1e6,
        m.batches,
        m.batch_occupancy(16) * 100.0
    );
    println!("serve_cnn OK");
    Ok(())
}
