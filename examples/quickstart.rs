//! Quickstart: the paper's core mechanics in ~60 lines.
//!
//! Reproduces the numeric examples of paper Figs. 2–4: manipulation,
//! approximation, packed multiplication on the bit-accurate DSP48E1
//! model, and fine-tuning.
//!
//! Run: `cargo run --release --example quickstart`

use sdmm::dsp::SdmmEngine;
use sdmm::manip::{approximate_signed, manipulate};
use sdmm::packing::{fine_tune_tuple, is_feasible_exact, pack_approx, Layout};

fn main() -> sdmm::error::Result<()> {
    // --- Fig. 2: parameter manipulation -----------------------------
    // |W| = 44 = 2^2 * (1 + 2^1 * 5): the 6-bit multiply W*I becomes a
    // 3-bit multiply (MW=5) plus shift/concat.
    let m = manipulate(44);
    println!("44 = 2^{} * (1 + 2^{} * {})", m.s, m.n, m.mw);
    assert_eq!((m.mw, m.n, m.s), (5, 1, 2));

    // --- Eq. 4: approximation ----------------------------------------
    // 23 needs MW=11 (4 bits) -> moved to the nearest representable 22.
    let (neg, a) = approximate_signed(23, 8).unwrap();
    println!("23 ~> {}{} (|err| = {})", if neg { "-" } else { "" }, a.approx, a.abs_error());
    assert_eq!(a.approx, 22);

    // --- Fig. 3 / Eq. 8: three 8-bit multiplications, ONE DSP op ----
    let layout = Layout::for_bits(8)?;
    let tuple = pack_approx(&layout, &[-44, 3, 96])?;
    let mut engine = SdmmEngine::new();
    for input in [-128i64, -77, 0, 51, 127] {
        let products = engine.execute(&tuple, &[input]);
        println!("I={input:>5}: products = {:?}", products);
        assert_eq!(products, tuple.expected_products(&[input]));
    }
    println!(
        "3 multiplications/op, {} DSP ops total (paper k=3 for 8-bit)",
        engine.stats().ops
    );

    // --- Fig. 4: fine-tuning in exact (non-approximated) mode --------
    let wide = vec![127, 127, 127]; // MW=63 each: cannot fit 25 bits
    assert!(!is_feasible_exact(&layout, &wide));
    let rep = fine_tune_tuple(&layout, &wide);
    println!(
        "fine-tune {:?} -> {:?} (Bray-Curtis {:.4})",
        rep.original, rep.tuned, rep.distance
    );
    assert!(is_feasible_exact(&layout, &rep.tuned));

    println!("quickstart OK");
    Ok(())
}
